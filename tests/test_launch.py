"""Launch-layer units that don't need multi-device compiles: HLO collective
parsing, roofline math, model-FLOPs accounting, layout equivalence,
sharding-policy rules."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.data.synthetic import make_batch
from repro.launch import analysis as A
from repro.models import decoder as dec

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %all-gather = bf16[2048,256]{1,0} all-gather(%p0), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %all-reduce = f32[64,64]{1,0} all-reduce(%c), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[16,64]{1,0} reduce-scatter(%big), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = bf16[8,32,64]{2,1,0} all-to-all(%x), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %cp = f32[4,4]{1,0} collective-permute(%y), source_target_pairs={{0,1},{1,0}}
  ROOT %t = tuple()
}
"""


def test_parse_collectives_operand_semantics():
    cs = A.parse_collectives(HLO_SAMPLE)
    # all-gather: operand = result / group  (2048*256*2 / 16)
    assert cs.bytes_by_kind["all-gather"] == 2048 * 256 * 2 // 16
    # all-reduce: operand = result
    assert cs.bytes_by_kind["all-reduce"] == 64 * 64 * 4
    # reduce-scatter: operand = result * group
    assert cs.bytes_by_kind["reduce-scatter"] == 16 * 64 * 4 * 4
    assert cs.bytes_by_kind["all-to-all"] == 8 * 32 * 64 * 2
    assert cs.bytes_by_kind["collective-permute"] == 4 * 4 * 4
    assert cs.count_by_kind["all-gather"] == 1
    assert cs.total_bytes == sum(cs.bytes_by_kind.values())


def test_roofline_terms_and_bottleneck():
    costs = {"flops": 197e12 * 0.010, "bytes": 819e9 * 0.002,
             "coll_all-reduce": 50e9 * 0.005}
    rep = A.roofline_from_raw("a", "s", "m", costs, chips=256,
                              model_flops_total=197e12 * 0.010 * 256 * 0.5)
    assert rep.compute_s == pytest.approx(0.010)
    assert rep.memory_s == pytest.approx(0.002)
    assert rep.collective_s == pytest.approx(0.005)
    assert rep.bottleneck == "compute"
    assert rep.useful_ratio == pytest.approx(0.5)


def test_combine_costs_linear():
    a = {"flops": 10.0, "bytes": 4.0}
    b = {"flops": 16.0, "bytes": 6.0, "coll_all-to-all": 2.0}
    out = A.combine_costs((-1.0, a), (2.0, b))
    assert out["flops"] == 22.0 and out["bytes"] == 8.0
    assert out["coll_all-to-all"] == 4.0


def test_count_params_moe_active():
    cfg = get_config("olmoe-1b-7b")
    n = A.count_params(cfg)
    assert n["total"] > n["active"] > n["dense"] > 0
    # 64 experts top-8: active expert share = 8/64 of expert params
    assert n["active"] - n["dense"] == pytest.approx(
        n["expert"] * cfg.top_k / cfg.num_experts, rel=1e-6)
    dense_cfg = get_config("gemma-2b")
    nd = A.count_params(dense_cfg)
    assert nd["active"] == nd["total"]


def test_model_flops_kinds():
    cfg = get_config("qwen1.5-0.5b")
    tr = A.model_flops(cfg, SHAPES["train_4k"], "train")
    pf = A.model_flops(cfg, SHAPES["prefill_32k"], "prefill")
    dc = A.model_flops(cfg, SHAPES["decode_32k"], "decode")
    assert tr == pytest.approx(3 * pf * (4096 * 256) / (32768 * 32))
    assert dc < pf / 1000


def test_list_layout_equivalent_to_scan():
    """Same weights, both layouts -> identical logits (the dry-run cost
    pass relies on this)."""
    cfg = get_config("recurrentgemma-9b").smoke()
    key = jax.random.PRNGKey(0)
    p_scan = dec.init_params(key, cfg, layout="scan")
    P_ = len(cfg.pattern)
    reps, rem = cfg.num_layers // P_, cfg.num_layers % P_
    layers = []
    for r in range(reps):
        for i in range(P_):
            layers.append(jax.tree_util.tree_map(
                lambda a: a[r], p_scan["layers_scan"][i]))
    for i in range(rem):
        layers.append(p_scan["layers_rem"][i])
    p_list = {k: v for k, v in p_scan.items()
              if not k.startswith("layers")}
    p_list["layers_list"] = tuple(layers)
    b = make_batch(key, cfg.vocab, 2, 12)
    l1, _, _ = dec.forward(p_scan, cfg, b)
    l2, _, _ = dec.forward(p_list, cfg, b)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-5, atol=2e-5)


def test_sharding_policy_rules():
    from repro import sharding as sh

    class FakeMI:
        model = 16
        data = 16
        pods = 1

    mi = FakeMI()
    # attention q: heads*hd divisible -> model-sharded on outputs
    spec = sh.param_pspec("layers_scan/0/attn/wq", (8, 1024, 2048), mi,
                          None, scanned=True)
    assert spec == P(None, None, "model")
    # kv columns divisible -> model-sharded; non-divisible -> replicated
    spec = sh.param_pspec("layers_rem/1/attn/wk", (1024, 256), mi, None,
                          scanned=False)
    assert spec == P(None, "model")
    spec = sh.param_pspec("layers_rem/1/attn/wk", (1024, 40), mi, None,
                          scanned=False)
    assert spec == P(None, None)
    # experts working layout
    spec = sh.param_pspec("layers_list/3/moe/experts/w_gate",
                          (16, 16, 4, 2048, 1024), mi, None, scanned=False)
    assert spec == P("data", "model", None, None, None)
    # experts canonical master
    spec = sh.param_pspec("layers_scan/0/moe/experts/w_up",
                          (10, 64, 2048, 1024), mi, None, scanned=True)
    assert spec == P(None, "model", "data", None)
    # embedding vocab-sharded
    spec = sh.param_pspec("embed", (262144, 5376), mi, None, scanned=False)
    assert spec == P("model", None)
