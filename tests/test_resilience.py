"""Resilience tests: fault injection, failure recovery, degraded-mode
scheduling, checkpoint hardening, and placement-aware resharding
(RESILIENCE.md, DESIGN.md §15).

Covers the full subsystem stack:

  * ``ResilienceConfig`` dict/CLI round-trips and validation;
  * ``FaultInjector`` determinism — scripted events fire exactly, seeded
    random rates replay identically, straggler windows open/close;
  * ``FleetController.fail_group`` lifecycle — emergency re-placement on
    the survivors, the feasibility floor (crash-at-floor regression:
    descriptive error, terminal ``infeasible`` event, state untouched),
    and crash-during-graceful-drain interleavings;
  * ``recover_from_crash`` at the manager level — victims evicted,
    re-enqueued at the FIFO head, retry accounting to the explicit
    ``failed`` terminal state, manager untouched when the fleet is at
    its floor;
  * ``StragglerMitigator`` deflate/restore and ``transfer_backoff``;
  * checkpoint hardening — a truncated npz raises CheckpointError naming
    the file, ``latest_checkpoint(valid_only=True)`` skips it, and
    ``restore_latest`` falls back to the previous valid step;
  * ``reshard_params`` — bit-exact round-trips across a grid/profile
    change (the ISSUE 9 acceptance bar), scanned stacks, pass-through
    leaves, and the guard rails;
  * serve-loop wiring — constructor validation, the co-located golden
    ServeReport staying byte-identical with ``enabled=False``, and
    end-to-end crash/straggler and transfer-fault runs.
"""
import argparse
import json
import pathlib

import numpy as np
import pytest

from repro.checkpoint import (CheckpointError, latest_checkpoint,
                              restore_latest, restore_checkpoint,
                              save_checkpoint)
from repro.configs import get_config
from repro.core.placement import Placement, asymmetric_placement
from repro.engine import (ConfigError, DeviceProfile, DisaggConfig,
                          FleetConfig, ResilienceConfig, ServeConfig)
from repro.fleet import FleetController, FleetInfeasibleError
from repro.resilience import (FaultEvent, FaultInjector, FaultPlan,
                              RetryTracker, StragglerMitigator,
                              recover_from_crash, reshard_params,
                              restore_resharded, transfer_backoff)
from repro.serve import BatchManager, Request, ServingSession, replay_trace

GOLDEN = pathlib.Path(__file__).parent / "golden" / \
    "serve_report_colocated.json"


def _req(i, arrival=0, p=3, g=4, vocab=64):
    rng = np.random.default_rng(i)
    return Request(req_id=i, arrival_step=arrival,
                   prompt=rng.integers(0, vocab, p), max_new=g)


def _ctl(groups=3, *, min_groups=2, spg=2, num_experts=8, slots=None,
         seed=0, **kw):
    prof = (DeviceProfile(weight=1.0, slots=slots),) if slots else None
    kw.setdefault("scale_check_every", 10 ** 6)
    return FleetController(
        FleetConfig(enabled=True, min_groups=min_groups, max_groups=groups,
                    slots_per_group=spg, group_profiles=prof, **kw),
        num_experts=num_experts, initial_groups=groups, seed=seed)


def _hosted(placement) -> set:
    flat = np.asarray(placement.flat())
    return set(flat[flat >= 0].tolist())


# ------------------------------------------------------ ResilienceConfig


def test_resilience_config_validation():
    assert ResilienceConfig().enabled is False
    with pytest.raises(ConfigError):
        ResilienceConfig(crash_rate=1.5)
    with pytest.raises(ConfigError):
        ResilienceConfig(straggler_factor=1.0)
    with pytest.raises(ConfigError):
        ResilienceConfig(straggler_threshold=0.5)
    with pytest.raises(ConfigError):
        ResilienceConfig(straggler_window=0)
    with pytest.raises(ConfigError):
        ResilienceConfig(max_retries=-1)
    with pytest.raises(ConfigError):
        ResilienceConfig(crash_steps="a,b")
    with pytest.raises(ConfigError):
        ResilienceConfig(crash_steps=(-1,))
    # CSV / list forms canonicalise to a sorted deduped tuple
    assert ResilienceConfig(crash_steps="5,1,5").crash_steps == (1, 5)
    assert ResilienceConfig(straggler_steps=[3, 3, 1]) \
        .straggler_steps == (1, 3)


def test_resilience_config_fault_kind_properties():
    rc = ResilienceConfig()
    assert not rc.has_group_faults and not rc.has_transfer_faults
    assert ResilienceConfig(crash_steps=(3,)).has_group_faults
    assert ResilienceConfig(straggler_rate=0.1).has_group_faults
    assert ResilienceConfig(transfer_fail_steps=(2,)).has_transfer_faults
    assert ResilienceConfig(transfer_fail_rate=0.2).has_transfer_faults
    assert not ResilienceConfig(transfer_fail_rate=0.2).has_group_faults


def test_resilience_config_dict_roundtrip():
    rc = ResilienceConfig(enabled=True, seed=7, crash_steps=(4, 9),
                          crash_rate=0.01, straggler_steps=(2,),
                          straggler_rate=0.05, straggler_factor=3.0,
                          straggler_window=8, straggler_threshold=1.5,
                          max_retries=2, transfer_fail_steps=(1, 3),
                          transfer_fail_rate=0.1, retry_backoff_steps=4,
                          max_transfer_retries=3)
    assert ResilienceConfig.from_dict(rc.to_dict()) == rc
    assert ResilienceConfig.from_dict(ResilienceConfig().to_dict()) == \
        ResilienceConfig()
    assert json.loads(json.dumps(rc.to_dict())) == rc.to_dict()
    with pytest.raises(ConfigError):
        ResilienceConfig.from_dict({"no_such_knob": 1})


def test_resilience_config_cli_roundtrip():
    rc = ResilienceConfig(enabled=True, seed=3, crash_steps=(4, 9),
                          straggler_steps=(2,), straggler_window=8,
                          max_retries=2, transfer_fail_steps=(1, 3),
                          transfer_fail_rate=0.1, retry_backoff_steps=4)
    ap = argparse.ArgumentParser()
    ResilienceConfig.add_cli_args(ap)
    assert ResilienceConfig.from_cli_args(ap.parse_args(rc.to_cli_args())) \
        == rc
    # defaults parse back to the default config
    assert ResilienceConfig.from_cli_args(ap.parse_args([])) == \
        ResilienceConfig()


# -------------------------------------------------------- FaultInjector


def test_fault_event_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent(at_step=0, kind="meteor")
    with pytest.raises(ValueError, match="at_step"):
        FaultEvent(at_step=-1, kind="crash")


def test_fault_injector_scripted_events_exact():
    plan = FaultPlan(events=(FaultEvent(at_step=5, kind="crash"),
                             FaultEvent(at_step=3, kind="straggler",
                                        factor=2.5, duration=4)))
    inj = FaultInjector(plan)
    live = [0, 1, 2]
    by_step = {s: inj.tick(s, live) for s in range(10)}
    assert by_step[5].crashes == 1
    assert sum(sf.crashes for sf in by_step.values()) == 1
    # straggler window [3, 7) on the newest live group, then recovery
    assert by_step[3].straggler_onsets == [(2, 2.5, 7)]
    for s in range(3, 7):
        assert by_step[s].straggler_factors == {2: 2.5}
    assert by_step[7].recovered == [2]
    assert by_step[7].straggler_factors == {}
    assert by_step[8].any is False
    kinds = [e["kind"] for e in inj.events_log]
    assert kinds == ["straggler_onset", "crash", "straggler_recover"]


def test_fault_injector_caps_and_monotonic_clock():
    # crashes are capped at the live group count; a second onset on an
    # already-straggling group is a no-op
    plan = FaultPlan(events=(FaultEvent(at_step=0, kind="crash"),
                             FaultEvent(at_step=0, kind="crash"),
                             FaultEvent(at_step=1, kind="straggler"),
                             FaultEvent(at_step=2, kind="straggler")))
    inj = FaultInjector(plan)
    assert inj.tick(0, [7]).crashes == 1
    assert len(inj.tick(1, [7]).straggler_onsets) == 1
    assert inj.tick(2, [7]).straggler_onsets == []
    with pytest.raises(ValueError, match="strictly increasing"):
        inj.tick(2, [7])
    # a straggler window dies silently with its group (no recovery event)
    sf = inj.tick(3, [9])
    assert sf.recovered == [] and sf.straggler_factors == {}


def test_fault_injector_seeded_rates_replay_identically():
    plan = FaultPlan(crash_rate=0.3, straggler_rate=0.2,
                     transfer_fail_rate=0.4, straggler_window=4, seed=5)
    a, b = FaultInjector(plan), FaultInjector(plan)
    for step in range(60):
        sa, sb = a.tick(step, [0, 1, 2]), b.tick(step, [0, 1, 2])
        assert (sa.crashes, sa.straggler_onsets, sa.recovered) == \
            (sb.crashes, sb.straggler_onsets, sb.recovered)
        assert [a.transfer_fails(step) for _ in range(3)] == \
            [b.transfer_fails(step) for _ in range(3)]
    assert a.events_log == b.events_log
    assert any(e["kind"] == "crash" for e in a.events_log)
    assert any(e["kind"] == "transfer_fail" for e in a.events_log)


# ----------------------------------------------------- recovery pieces


def test_retry_tracker_explicit_terminal_state():
    with pytest.raises(ValueError):
        RetryTracker(-1)
    t = RetryTracker(1)
    r4, r5 = _req(4), _req(5)
    assert t.account([r4, r5]) == ([r4, r5], [])
    retry, failed = t.account([r4])
    assert retry == [] and failed == [r4]          # second crash: terminal
    assert [r.req_id for r in t.failed] == [4]
    # max_retries=0: victims fail on the first crash, never silently lost
    t0 = RetryTracker(0)
    assert t0.account([r5]) == ([], [r5])


def test_transfer_backoff_capped_exponential():
    assert [transfer_backoff(n, 2, 3) for n in range(1, 7)] == \
        [2, 4, 8, 16, 16, 16]
    assert transfer_backoff(1, 1, 0) == transfer_backoff(9, 1, 0) == 1
    with pytest.raises(ValueError, match="1-based"):
        transfer_backoff(0, 2, 3)


def test_straggler_mitigator_deflates_and_restores():
    with pytest.raises(ValueError):
        StragglerMitigator(1.0)
    with pytest.raises(ValueError):
        StragglerMitigator(2.0, ema_decay=1.0)
    with pytest.raises(ValueError):
        StragglerMitigator(2.0, floor=0.0)
    m = StragglerMitigator(2.0)
    healthy = {0: 10.0, 1: 10.0, 2: 10.0}
    assert m.observe(healthy) == {0: 1.0, 1: 1.0, 2: 1.0}
    mult = m.observe({0: 10.0, 1: 10.0, 2: 80.0})
    assert mult[0] == mult[1] == 1.0 and mult[2] < 1.0
    # deflation ~ median/ewma, never below the floor
    assert m.floor <= mult[2] <= 10.0 / (2.0 * 10.0) + 1e-9
    for _ in range(10):
        mult = m.observe(healthy)
    assert mult == {0: 1.0, 1: 1.0, 2: 1.0}        # full restore
    # a crashed group drops out of the EWMA state entirely
    mult = m.observe({0: 10.0, 1: 10.0})
    assert set(mult) == {0, 1} and 2 not in m.ema


def test_straggler_mitigator_two_group_lower_median():
    # regression: with 2 groups an interpolated median averages the
    # straggler in and the threshold is unreachable — the lower order
    # statistic must be used
    m = StragglerMitigator(2.0)
    mult = {}
    for _ in range(6):
        mult = m.observe({0: 10.0, 1: 40.0})
    assert mult[0] == 1.0 and mult[1] < 1.0


# ---------------------------------------------- fail_group lifecycle


def test_fail_group_emergency_repack():
    ctl = _ctl(3, slots=5)                    # survivors keep headroom
    ctl.set_weight_override(2, 0.5)
    ev = ctl.fail_group(2, step=4)
    assert ev["kind"] == "crash" and ev["group"] == 2
    assert ev["active_groups"] == 2 and ev["capacity"] == 4
    assert ev["moved_slots"] > 0              # emergency re-placement
    assert ctl.placement.num_devices == 2
    assert _hosted(ctl.placement) == set(range(8))
    assert ctl.crashes == 1 and ctl.summary()["crashes"] == 1
    assert ctl.weight_overrides == {}         # override died with the group
    with pytest.raises(ValueError, match="no group 2"):
        ctl.fail_group(2, step=5)


def test_fail_group_at_floor_raises_and_leaves_state_untouched():
    # regression (satellite): 2 groups x 4 default slots host exactly
    # E=8 — a crash is infeasible and must not corrupt the fleet
    ctl = _ctl(2)
    before = np.asarray(ctl.placement.flat()).copy()
    with pytest.raises(FleetInfeasibleError, match="feasibility floor"):
        ctl.fail_group(1, step=3)
    assert ctl.num_groups == 2 and ctl.capacity == 4
    assert np.array_equal(np.asarray(ctl.placement.flat()), before)
    assert ctl.crashes == 0
    ev = ctl.events[-1]
    assert ev["kind"] == "infeasible" and ev["group"] == 1
    assert ev["survivor_slots"] == 4


def test_fail_group_during_graceful_drain():
    # regression (satellite): crash interleaved with an in-flight drain
    from repro.fleet import FleetSignals
    ctl = _ctl(3, slots=4, num_experts=4, scale_check_every=2,
               scale_up_threshold=0.9, scale_down_threshold=0.35,
               drain_grace_steps=10)
    ev = ctl.observe(FleetSignals(step=2, utilization=0.0, queue_depth=0,
                                  active_slots=0, capacity=ctl.capacity,
                                  busy_above_capacity=0), 2)
    assert [e["kind"] for e in ev] == ["drain"] and ctl.draining == 2
    # 1. the draining group itself dies: no repack (already zero-budget),
    #    it just drops immediately
    ev = ctl.fail_group(2, step=3)
    assert ev["moved_slots"] == 0 and ctl.num_groups == 2
    assert ctl.draining is None
    assert _hosted(ctl.placement) == set(range(4))
    # 2. an *active* group dies while another drains: survivors repack
    ctl2 = _ctl(3, slots=4, num_experts=4, scale_check_every=2,
                scale_up_threshold=0.9, scale_down_threshold=0.35,
                drain_grace_steps=10)
    ctl2.observe(FleetSignals(step=2, utilization=0.0, queue_depth=0,
                              active_slots=0, capacity=ctl2.capacity,
                              busy_above_capacity=0), 2)
    ev = ctl2.fail_group(0, step=3)
    assert ev["kind"] == "crash" and ctl2.num_groups == 2
    assert ctl2.draining == 2                 # the drain is still pending
    flat = np.asarray(ctl2.placement.flat())
    assert (flat[1:] < 0).all()               # draining rows stay empty
    assert _hosted(ctl2.placement) == set(range(4))


# -------------------------------------------- recover_from_crash


def _manager(ctl, n_reqs):
    width = ctl.cfg.max_groups * ctl.cfg.slots_per_group
    bm = BatchManager(ServeConfig(max_batch=width, max_seq=16))
    bm.set_slot_limit(ctl.capacity)
    for i in range(n_reqs):
        bm.submit(_req(i))
    return bm


def test_recover_from_crash_requeues_at_fifo_head():
    ctl = _ctl(3, slots=5)
    bm = _manager(ctl, 7)
    bm.admit_ready(0)
    assert bm.n_active == 6 and [r.req_id for r in bm.queue] == [6]
    tracker = RetryTracker(3)
    rec = recover_from_crash(bm, ctl, tracker, step=1)
    # the newest group's slots [4, 6) are evicted, re-enqueued at the head
    assert [r.req_id for r in rec.victims] == [4, 5]
    assert [r.req_id for r in rec.requeued] == [4, 5] and not rec.failed
    assert [r.req_id for r in bm.queue] == [4, 5, 6]
    assert bm.n_active == 4 and bm.slot_limit == ctl.capacity == 4
    assert rec.event["kind"] == "crash"
    assert tracker.counts == {4: 1, 5: 1}
    d = rec.to_event()
    assert d["victims"] == d["requeued"] == [4, 5] and d["failed"] == []


def test_recover_from_crash_terminal_failed_state():
    ctl = _ctl(3, slots=5)
    bm = _manager(ctl, 6)
    bm.admit_ready(0)
    rec = recover_from_crash(bm, ctl, RetryTracker(0), step=1)
    assert [r.req_id for r in rec.failed] == [4, 5] and not rec.requeued
    assert not bm.queue                        # failed never re-enqueue


def test_recover_from_crash_at_floor_leaves_manager_untouched():
    ctl = _ctl(2)                              # exactly feasible fleet
    bm = _manager(ctl, 5)
    bm.admit_ready(0)
    assert bm.n_active == 4
    with pytest.raises(FleetInfeasibleError):
        recover_from_crash(bm, ctl, RetryTracker(3), step=1)
    assert bm.n_active == 4 and bm.slot_limit == 4
    assert [r.req_id for r in bm.queue] == [4]


def test_batch_manager_crash_primitives():
    bm = BatchManager(ServeConfig(max_batch=4, max_seq=16))
    for i in range(3):
        bm.submit(_req(i))
    bm.admit_ready(0)
    reserved = bm.reserved_tokens
    victims = bm.evict_range(1, 4)
    assert [v.request.req_id for v in victims] == [1, 2]
    assert bm.n_active == 1 and bm.reserved_tokens < reserved
    with pytest.raises(ValueError):
        bm.evict_range(2, 5)
    bm.requeue_front([v.request for v in victims])
    assert [r.req_id for r in bm.queue] == [1, 2]
    with pytest.raises(ValueError, match="decode"):
        BatchManager(ServeConfig(max_batch=2, max_seq=16),
                     role="decode").requeue_front([])


# ------------------------------------------------ checkpoint hardening


def _ckpt_dir(tmp_path, steps=(1, 2, 3)):
    d = str(tmp_path / "ckpts")
    for s in steps:
        save_checkpoint(d, s, {"w": np.full((4,), float(s)),
                               "b": np.arange(3) * s})
    return d


def test_truncated_checkpoint_raises_naming_file(tmp_path):
    d = _ckpt_dir(tmp_path)
    bad = pathlib.Path(d) / "ckpt_00000003.npz"
    bad.write_bytes(bad.read_bytes()[:50])     # truncate mid-archive
    template = {"w": np.zeros(4), "b": np.zeros(3, np.int64)}
    with pytest.raises(CheckpointError, match="ckpt_00000003.npz"):
        restore_checkpoint(str(bad), template)
    # the structural-mismatch contract is unchanged: KeyError, not
    # CheckpointError, for a template leaf the payload never had
    good = pathlib.Path(d) / "ckpt_00000002.npz"
    with pytest.raises(KeyError, match="extra"):
        restore_checkpoint(str(good), {**template, "extra": np.zeros(1)})


def test_latest_checkpoint_valid_only_skips_unreadable(tmp_path):
    d = _ckpt_dir(tmp_path)
    bad = pathlib.Path(d) / "ckpt_00000003.npz"
    bad.write_bytes(bad.read_bytes()[:50])
    assert latest_checkpoint(d).endswith("ckpt_00000003.npz")
    assert latest_checkpoint(d, valid_only=True) \
        .endswith("ckpt_00000002.npz")
    assert latest_checkpoint(str(tmp_path / "nowhere")) is None


def test_restore_latest_falls_back_to_previous_valid_step(tmp_path):
    d = _ckpt_dir(tmp_path)
    bad = pathlib.Path(d) / "ckpt_00000003.npz"
    bad.write_bytes(bad.read_bytes()[:50])
    template = {"w": np.zeros(4), "b": np.zeros(3, np.int64)}
    tree, path = restore_latest(d, template)
    assert path.endswith("ckpt_00000002.npz")
    assert np.array_equal(tree["w"], np.full((4,), 2.0))
    # every step corrupt: a descriptive terminal error, never silence
    for p in pathlib.Path(d).glob("ckpt_*.npz"):
        p.write_bytes(p.read_bytes()[:50])
    with pytest.raises(CheckpointError, match="no restorable"):
        restore_latest(d, template)


# ----------------------------------------------- checkpoint resharding


def _placements():
    rng = np.random.default_rng(0)
    old = asymmetric_placement(1, 4, 8, rng.uniform(1, 9, 8), seed=1,
                               num_samples=16,
                               slot_budgets=np.full(4, 3, np.int64))
    new = asymmetric_placement(1, 3, 8, rng.uniform(1, 9, 8), seed=2,
                               num_samples=16,
                               slot_budgets=np.full(3, 4, np.int64))
    return old, new


def _working(masters, placement):
    """The runtime's working layout: canonical gathered by the table
    (empty slots hold expert 0 — launch.runtime)."""
    return np.asarray(masters)[np.maximum(
        np.asarray(placement.table), 0)]


def test_reshard_params_bit_exact_roundtrip():
    old, new = _placements()
    rng = np.random.default_rng(3)
    masters = rng.standard_normal((8, 3, 5)).astype(np.float32)
    scanned = rng.standard_normal((2, 8, 6)).astype(np.float32)
    dense = rng.standard_normal((7, 5))
    tree = {"moe": {"w": _working(masters, old),
                    "stack": np.stack([_working(scanned[i], old)
                                       for i in range(2)])},
            "dense": dense}
    out = reshard_params(tree, old, new)
    assert np.array_equal(out["moe"]["w"], _working(masters, new))
    assert np.array_equal(
        out["moe"]["stack"],
        np.stack([_working(scanned[i], new) for i in range(2)]))
    assert out["dense"] is dense               # pass-through untouched
    # round-trip back onto the old grid recovers the original bits
    back = reshard_params(out, new, old)
    assert np.array_equal(back["moe"]["w"], tree["moe"]["w"])
    assert np.array_equal(back["moe"]["stack"], tree["moe"]["stack"])


def test_reshard_params_profile_budget_guard():
    old, new = _placements()
    tree = {"w": _working(np.arange(8.0).reshape(8, 1), old)}
    ok = [DeviceProfile(weight=1.0, slots=4)] * 3
    reshard_params(tree, old, new, profiles=ok)        # fits: no raise
    with pytest.raises(ValueError, match="slot budgets"):
        reshard_params(tree, old, new,
                       profiles=[DeviceProfile(weight=1.0, slots=1)] * 3)
    with pytest.raises(ValueError, match="3-device"):
        reshard_params(tree, old, new,
                       profiles=[DeviceProfile(weight=1.0)] * 2)


def test_reshard_params_guard_rails():
    old, new = _placements()
    seven = Placement(np.array([[[0, 1, 2, 3], [4, 5, 6, -1]]], np.int32),
                      7)
    with pytest.raises(ValueError, match="num_experts"):
        reshard_params({}, old, seven)
    # an old placement missing an expert cannot recover its weights —
    # Placement itself forbids that state, so exercise the defensive
    # check in _first_replica_index directly with a stand-in
    from repro.resilience.reshard import _first_replica_index

    class _Gappy:
        num_experts = 8
        table = np.array([[[0, 1, 2], [3, 4, 5]]], np.int32)

        def flat(self):
            return self.table[0]

    with pytest.raises(ValueError, match=r"\[6, 7\]"):
        _first_replica_index(_Gappy())


def test_restore_resharded_end_to_end(tmp_path):
    old, new = _placements()
    rng = np.random.default_rng(4)
    masters = rng.standard_normal((8, 4)).astype(np.float32)
    path = save_checkpoint(str(tmp_path), 5,
                           {"moe": _working(masters, old)})
    template = {"moe": np.zeros_like(_working(masters, new))}
    out = restore_resharded(path, template, old, new)
    assert np.array_equal(out["moe"], _working(masters, new))
    with pytest.raises(ValueError, match="resharded leaf"):
        restore_resharded(path, {"moe": np.zeros((1, 9, 9, 4))}, old, new)


# ----------------------------------------------------- serve wiring


def test_serving_session_resilience_validation():
    cfg = get_config("qwen1.5-0.5b").smoke()
    sc = ServeConfig(max_batch=2, max_seq=16)
    fc = FleetConfig(enabled=True, min_groups=1, max_groups=2,
                     slots_per_group=2)
    dg = DisaggConfig(enabled=True, prefill_slots=2, decode_slots=1,
                      handoff_depth=1)
    with pytest.raises(ValueError, match="needs a fleet"):
        ServingSession(cfg, sc, resilience=ResilienceConfig(enabled=True))
    with pytest.raises(ValueError, match="no device group"):
        ServingSession(cfg, sc, disagg=dg,
                       resilience=ResilienceConfig(enabled=True,
                                                   crash_steps=(3,)))
    with pytest.raises(ValueError, match="no transfer boundary"):
        ServingSession(cfg, sc, fleet=fc,
                       resilience=ResilienceConfig(
                           enabled=True, transfer_fail_rate=0.1))
    # disabled config: no machinery armed, no validation tripwires
    sess = ServingSession(cfg, sc,
                          resilience=ResilienceConfig(enabled=False))
    assert sess.resilience is None


def _canonical_report(rep) -> dict:
    d = rep.to_dict()
    for k in ("wall_s", "gen_tokens_per_s", "tokens_per_s",
              "latency_ms", "ttft_ms"):
        d.pop(k)
    for r in d["per_request"]:
        r.pop("latency_ms")
        r.pop("ttft_ms")
    return d


def test_serve_report_golden_with_resilience_disabled():
    """ISSUE 9 acceptance: ResilienceConfig(enabled=False) keeps the
    co-located ServeReport byte-identical to the golden fixture."""
    arrivals = [(0, 6, 5), (0, 4, 3), (2, 5, 4), (7, 6, 6), (9, 3, 3)]
    out = {}
    for name, arch in (("dense", "qwen1.5-0.5b"),
                       ("moe", "paper-gpt-32x1.3b")):
        cfg = get_config(arch).smoke()
        sess = ServingSession(cfg, ServeConfig(max_batch=3, max_seq=24),
                              seed=0,
                              resilience=ResilienceConfig(enabled=False))
        rep = sess.run(replay_trace(arrivals, vocab=cfg.vocab, seed=11))
        assert "resilience" not in rep.to_dict()
        out[name] = _canonical_report(rep)
    blob = json.dumps(out, sort_keys=True, indent=1) + "\n"
    assert blob == GOLDEN.read_text(), \
        "disabled resilience changed the co-located ServeReport"


def test_serving_session_fleet_crash_end_to_end():
    cfg = get_config("paper-gpt-32x1.3b").smoke()
    fc = FleetConfig(enabled=True, min_groups=2, max_groups=3,
                     slots_per_group=2, scale_check_every=10 ** 6,
                     group_profiles=(DeviceProfile(weight=1.0, slots=4),))
    rc = ResilienceConfig(enabled=True, crash_steps=(12,),
                          straggler_steps=(2,), straggler_window=6,
                          max_retries=3)
    sess = ServingSession(cfg, ServeConfig(max_batch=2, max_seq=16),
                          seed=0, fleet=fc, resilience=rc)
    reqs = [_req(i, arrival=0, p=4, g=8) for i in range(8)]
    # first run pays the jit compiles: their multi-hundred-ms steps
    # dominate the latency EWMA and mask the injected straggler.  The
    # second run (same session: warm caches, fresh per-run fleet and
    # injector) sees clean step times — that is the run under test.
    sess.run(reqs, max_steps=300)
    rep = sess.run(reqs, max_steps=300)
    d = rep.to_dict()
    res = d["resilience"]
    assert res["enabled"] is True and res["crashes"] == 1
    assert res["requeues"] >= 1
    # conservation: every request served or explicitly failed, never lost
    served = sorted(r.req_id for r in rep.records)
    assert sorted(served + res["failed_requests"]) == list(range(8))
    assert res["failed_requests"] == []        # retries sufficed here
    assert res["straggler_deflations"] >= 1
    kinds = {e["kind"] for e in res["events"]}
    assert "crash" in kinds and "straggler_deflate" in kinds
    assert "straggler_restore" in kinds
    assert any(e["kind"] == "crash" for e in res["injected"])
    assert d["fleet"]["crashes"] == 1
    assert "resilience:" in rep.summary()


def test_serving_session_transfer_faults_end_to_end():
    cfg = get_config("qwen1.5-0.5b").smoke()
    dg = DisaggConfig(enabled=True, prefill_slots=3, decode_slots=2,
                      handoff_depth=2)
    rc = ResilienceConfig(enabled=True, transfer_fail_steps=(1, 2, 3, 4),
                          retry_backoff_steps=1)
    arrivals = [(0, 6, 5), (0, 4, 3), (2, 5, 4), (7, 6, 6), (9, 3, 3)]
    sess = ServingSession(cfg, ServeConfig(max_batch=3, max_seq=24),
                          seed=0, disagg=dg, resilience=rc)
    rep = sess.run(replay_trace(arrivals, vocab=cfg.vocab, seed=11))
    assert len(rep.records) == 5 and rep.rejected == 0
    for r, (_, _, g) in zip(sorted(rep.records, key=lambda r: r.req_id),
                            arrivals):
        assert r.n_generated == g              # retried, never dropped
    res = rep.to_dict()["resilience"]
    assert res["transfer_failures"] >= 1
    # retries = failures of an already-retried attempt: a strict subset
    assert 0 <= res["transfer_retries"] <= res["transfer_failures"]
    assert res["crashes"] == 0 and res["failed_requests"] == []
    assert all(e["kind"] == "transfer_fail" for e in res["events"])
    assert "resilience:" in rep.summary()
