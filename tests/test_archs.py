"""Per-architecture smoke tests (prompt requirement): reduced same-family
variant (2 layers, d_model <= 512, <= 4 experts), one forward/train step on
CPU, output shapes + no NaNs; plus decode-vs-forward logit consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, PAPER, get_config
from repro.data.synthetic import frontend_stub_batch, make_batch
from repro.models import decoder as dec
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.loop import TrainState, make_train_step

ALL = ASSIGNED + PAPER


def _batch(cfg, key, b, t):
    if cfg.frontend_stub == "vision":
        return frontend_stub_batch(key, cfg, b, t)
    return make_batch(key, cfg.vocab, b, t)


@pytest.mark.parametrize("name", ALL)
def test_smoke_forward_and_train_step(name):
    cfg = get_config(name).smoke()
    assert cfg.d_model <= 512 and cfg.num_layers <= max(len(cfg.pattern), 2)
    if cfg.moe:
        assert cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = dec.init_params(key, cfg)
    b, t = 2, 16
    batch = _batch(cfg, key, b, t)

    logits, moe, _ = dec.forward(params, cfg, batch)
    assert logits.shape == (b, t, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{name}: NaN/inf logits"

    ts = TrainState(master=params, opt=adamw_init(params),
                    solver=dec.init_solver_states(cfg, 1),
                    step=jnp.zeros((), jnp.int32))
    step = make_train_step(cfg, n_micro=1)
    ts2, m = step(ts, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"])) and float(m["grad_norm"]) > 0
    if cfg.moe:
        assert float(m["overflow"]) == 0.0
    # params actually changed
    changed = any(
        float(jnp.abs(a - b_).max()) > 0
        for a, b_ in zip(jax.tree_util.tree_leaves(ts.master),
                         jax.tree_util.tree_leaves(ts2.master)))
    assert changed


@pytest.mark.parametrize("name", [
    "gemma-2b", "gemma3-27b", "rwkv6-7b", "recurrentgemma-9b",
    "olmoe-1b-7b", "qwen1.5-0.5b", "musicgen-medium",
])
def test_decode_matches_forward(name):
    """Token-by-token decode with caches reproduces the parallel forward's
    next-token logits (teacher forcing) — validates every cache type."""
    cfg = get_config(name).smoke()
    key = jax.random.PRNGKey(1)
    params = dec.init_params(key, cfg)
    b, t = 2, 12
    tokens = jax.random.randint(key, (b, t), 0, cfg.vocab)
    ref_logits, _, _ = dec.forward(params, cfg, {"tokens": tokens})

    state = dec.init_decode_state(cfg, b, t)
    outs = []
    for i in range(t):
        lg, state = dec.decode_step(params, cfg, state,
                                    {"tokens": tokens[:, i:i + 1]})
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_qwen2vl_embeds_decode():
    """VLM backbone consumes stub patch embeddings; decode continues with
    token inputs (generated text)."""
    cfg = get_config("qwen2-vl-7b").smoke()
    key = jax.random.PRNGKey(2)
    params = dec.init_params(key, cfg)
    batch = frontend_stub_batch(key, cfg, 2, 16)
    logits, _, _ = dec.forward(params, cfg, batch)
    assert jnp.isfinite(logits).all()
    state = dec.init_decode_state(cfg, 2, 32)
    lg, state = dec.decode_step(params, cfg, state,
                                {"embeds": batch["embeds"][:, :1]})
    lg, state = dec.decode_step(params, cfg, state,
                                {"tokens": jnp.argmax(lg[:, -1], -1)[:, None]})
    assert jnp.isfinite(lg).all()


def test_configs_match_assignment():
    """The registered configs carry the exact assigned hyper-parameters."""
    expect = {
        "rwkv6-7b": (32, 4096, None, None, 14336, 65536),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
    }
    for name, (nl, dm, h, kv, dff, v) in expect.items():
        cfg = get_config(name)
        assert cfg.num_layers == nl and cfg.d_model == dm, name
        if h is not None:
            assert cfg.num_heads == h and cfg.num_kv_heads == kv, name
        assert cfg.d_ff == dff and cfg.vocab == v, name
        assert cfg.source, f"{name} missing source citation"
    moe_expect = {"dbrx-132b": (16, 4), "olmoe-1b-7b": (64, 8)}
    for name, (e, k) in moe_expect.items():
        cfg = get_config(name)
        assert cfg.moe and cfg.num_experts == e and cfg.top_k == k
    # family coverage: 6 arch types
    fams = {get_config(n).family for n in ASSIGNED}
    assert fams == {"ssm", "hybrid", "vlm", "audio", "dense", "moe"}


def test_long_context_eligibility():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §5)."""
    runs = {n for n in ASSIGNED if get_config(n).sub_quadratic}
    assert runs == {"rwkv6-7b", "recurrentgemma-9b", "gemma3-27b",
                    "gemma3-4b"}
