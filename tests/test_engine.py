"""The unified engine API: registries, typed configs, the MicroEPEngine
facade, and the architectural guard that nothing outside ``repro.engine`` /
``repro.core`` hand-wires the scheduling machinery.

This file is the ONE place allowed to construct ``ScheduleStatics`` /
``MicroEPScheduler`` directly outside core/engine — the legacy hand-wired
path lives here solely as the reference for the bit-identical equivalence
tests (and the grep guard below excludes this file for that reason).
"""
import argparse
import json
import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.placement import latin_placement
from repro.core.scheduler import MicroEPScheduler, ScheduleStatics
from repro.engine import (ConfigError, MicroEPEngine, PlacementSpec,
                          Registry, RegistryError, RuntimeConfig,
                          SchedulePolicy, baseline_systems,
                          placement_strategies, register_placement_strategy)
from repro.moe import dispatch as D
from repro.moe.baselines import baseline_max_load
from repro.moe.layer import MoEFFNSpec

REPO = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------- registries


def test_registry_register_lookup_and_unknown_key():
    reg = Registry("test thing")

    @reg.register("alpha")
    def alpha():
        return "a"

    reg.register("beta", lambda: "b")
    assert reg.get("alpha") is alpha
    assert reg["beta"]() == "b"
    assert reg.names() == ("alpha", "beta")
    assert "alpha" in reg and len(reg) == 2
    with pytest.raises(RegistryError) as ei:
        reg.get("gamma")
    # the error lists every registered option
    assert "alpha" in str(ei.value) and "beta" in str(ei.value)
    # dict-style consumers keep dict semantics on unknown keys
    assert "gamma" not in reg
    assert reg.get("gamma", None) is None
    assert reg.get("beta", None)() == "b"
    with pytest.raises(RegistryError):
        reg["gamma"]


def test_registry_duplicate_and_override():
    reg = Registry("test thing")
    reg.register("x", lambda: 1)
    with pytest.raises(RegistryError, match="already registered"):
        reg.register("x", lambda: 2)
    reg.register("x", lambda: 2, override=True)
    assert reg.get("x")() == 2
    with pytest.raises(RegistryError):
        reg.register("", lambda: 3)


def test_builtin_placement_strategies_registered():
    assert {"vanilla", "random", "latin", "asymmetric"} <= set(
        placement_strategies.names())
    p = placement_strategies.get("latin")(2, 4, 8)
    assert p.num_devices == 8
    # asymmetric without loads: actionable error
    with pytest.raises(RegistryError, match="loads"):
        placement_strategies.get("asymmetric")(2, 4, 8)


def test_custom_placement_strategy_plugs_into_engine():
    @register_placement_strategy("test-reversed-latin")
    def reversed_latin(rows, cols, num_experts, *, seed=0, loads=None):
        p = latin_placement(rows, cols, num_experts)
        return type(p)(p.table[::-1].copy(), num_experts)

    try:
        eng = MicroEPEngine.build(8, (2, 4),
                                  placement="test-reversed-latin")
        ref = latin_placement(2, 4, 8)
        np.testing.assert_array_equal(eng.placement.table,
                                      ref.table[::-1])
        out = eng.schedule(jnp.ones((8, 8), jnp.int32))
        assert np.isfinite(float(out.max_load))
    finally:
        placement_strategies.unregister("test-reversed-latin")


def test_baseline_system_registry():
    assert {"megatron", "deepspeed", "gshard", "smartmoe", "flexmoe"} <= set(
        baseline_systems.names())
    m, dropped = baseline_max_load("megatron", np.ones(8), 4, 2)
    assert m == 2.0 and dropped == 0.0
    with pytest.raises(RegistryError, match="megatron"):
        baseline_max_load("nope", np.ones(8), 4, 2)
    # legacy alias is the live registry
    from repro.moe.baselines import SYSTEMS
    assert SYSTEMS is baseline_systems


# -------------------------------------------------------------- typed config


def test_schedule_policy_validation_lists_options():
    with pytest.raises(ConfigError, match="microep"):
        SchedulePolicy(mode="magic")
    with pytest.raises(ConfigError, match="proportional"):
        SchedulePolicy(sequencing="alphabetical")
    with pytest.raises(ConfigError, match="sweeps"):
        SchedulePolicy(sweeps=0)


def test_placement_spec_validation_and_loads_normalization():
    with pytest.raises(ConfigError):
        PlacementSpec(strategy="")
    with pytest.raises(ConfigError):
        PlacementSpec(seed="zero")
    spec = PlacementSpec(strategy="asymmetric",
                         loads=np.arange(4, dtype=np.float32))
    assert spec.loads == (0.0, 1.0, 2.0, 3.0)
    assert hash(spec)  # stays hashable with array-ish loads


def test_runtime_config_validation():
    with pytest.raises(ConfigError, match="layout"):
        RuntimeConfig(layout="stacked")
    with pytest.raises(ConfigError, match="dtype"):
        RuntimeConfig(dtype="float64")
    with pytest.raises(ConfigError, match="capacity_factor"):
        RuntimeConfig(capacity_factor=0.0)
    with pytest.raises(ConfigError, match="impl"):
        RuntimeConfig(impl="cuda")
    # jnp dtypes normalize to the canonical string name
    assert RuntimeConfig(dtype=jnp.bfloat16).dtype == "bfloat16"
    assert RuntimeConfig(dtype=jnp.float32).jax_dtype == jnp.float32
    # a bare strategy string is promoted to a PlacementSpec
    assert RuntimeConfig(placement="random").placement == \
        PlacementSpec(strategy="random")


@pytest.mark.parametrize("cfg", [
    RuntimeConfig(),
    RuntimeConfig(placement=PlacementSpec("random", seed=3),
                  policy=SchedulePolicy(mode="vanilla", sweeps=2,
                                        locality=False,
                                        sequencing="greedy"),
                  dtype="float32", capacity_factor=1.25, impl="interpret",
                  remat=False, unroll=True, layout="list",
                  seq_parallel=True),
    RuntimeConfig(placement=PlacementSpec("asymmetric",
                                          loads=(3.0, 1.0, 2.0, 2.0))),
])
def test_runtime_config_dict_round_trip(cfg):
    d = cfg.to_dict()
    json.dumps(d)  # must be JSON-serializable as-is
    assert RuntimeConfig.from_dict(d) == cfg


def test_config_from_dict_rejects_unknown_fields():
    with pytest.raises(ConfigError, match="typo"):
        RuntimeConfig.from_dict({"typo": 1})
    with pytest.raises(ConfigError, match="mode"):
        SchedulePolicy.from_dict({"mode": "microep", "modes": "x"})


def test_runtime_config_legacy_kwargs_shim():
    cfg = RuntimeConfig.from_kwargs(
        dtype=jnp.float32, placement_strategy="random", seed=5,
        mode="vanilla", sweeps=9, locality=False, sequencing="greedy",
        capacity_factor=4.0, impl="ref", remat=False, unroll=True,
        layout="list", seq_parallel=True)
    assert cfg.placement == PlacementSpec("random", seed=5)
    assert cfg.policy == SchedulePolicy(mode="vanilla", sweeps=9,
                                        locality=False, sequencing="greedy")
    assert cfg.dtype == "float32" and cfg.capacity_factor == 4.0
    with pytest.raises(ConfigError, match="placement_strategy"):
        RuntimeConfig.from_kwargs(placement_stragety="latin")


@pytest.mark.parametrize("cfg", [
    RuntimeConfig(),
    RuntimeConfig(placement=PlacementSpec("vanilla", seed=7),
                  policy=SchedulePolicy(mode="vanilla", sweeps=3,
                                        locality=False,
                                        sequencing="greedy"),
                  dtype="float16", capacity_factor=1.5, impl="pallas",
                  remat=False, unroll=True, layout="list",
                  seq_parallel=True),
])
def test_runtime_config_cli_round_trip(cfg):
    ap = argparse.ArgumentParser()
    RuntimeConfig.add_cli_args(ap)
    got = RuntimeConfig.from_cli_args(ap.parse_args(cfg.to_cli_args()))
    assert got == cfg
    # no flags at all reproduces the entry point's chosen defaults
    ap2 = argparse.ArgumentParser()
    RuntimeConfig.add_cli_args(ap2, defaults=cfg)
    assert RuntimeConfig.from_cli_args(ap2.parse_args([])) == cfg


# ------------------------------------------------------------------- facade


def test_engine_build_forms_agree():
    a = MicroEPEngine.build(8, (2, 4), placement="latin")
    b = MicroEPEngine.build(8, (2, 4), placement=PlacementSpec("latin"))
    c = MicroEPEngine.build(8, (2, 4),
                            placement=latin_placement(2, 4, 8))
    np.testing.assert_array_equal(a.placement.table, b.placement.table)
    np.testing.assert_array_equal(a.placement.table, c.placement.table)
    v = MicroEPEngine.build(8, (2, 4), placement="vanilla",
                            policy="vanilla")
    assert v.policy.mode == "vanilla"
    assert a.grid == (2, 4) and a.num_devices == 8 and a.num_experts == 8


def test_engine_build_rejects_bad_inputs():
    with pytest.raises(RegistryError, match="latin"):
        MicroEPEngine.build(8, (2, 4), placement="no-such-strategy")
    with pytest.raises(ConfigError, match="8"):
        MicroEPEngine.build(8, (2, 4),
                            placement=latin_placement(4, 2, 8))
    with pytest.raises(ConfigError):
        MicroEPEngine.build(8, (2, 4), policy=42)


def test_engine_dispatch_statics_cached():
    eng = MicroEPEngine.build(8, (2, 4))
    s1 = eng.dispatch_statics(64, 2)
    s2 = eng.dispatch_statics(64, 2)
    s3 = eng.dispatch_statics(128, 2)
    assert s1 is s2 and s1 is not s3
    spec = eng.moe_spec(64, 2, activation="swiglu")
    assert isinstance(spec, MoEFFNSpec)
    assert spec.statics is s1 and spec.scheduler is eng.scheduler


# ------------------------- equivalence with the legacy hand-wired pipeline


@pytest.mark.parametrize("mode,strategy", [
    ("microep", "latin"), ("vanilla", "vanilla"), ("microep", "random"),
])
def test_engine_schedule_matches_legacy_bit_for_bit(mode, strategy):
    """MicroEPEngine must be pure plumbing: byte-identical Schedule results
    to the pre-engine hand-wired construction path."""
    rows, cols, e = 2, 4, 8
    policy = SchedulePolicy(mode=mode, sweeps=12)
    eng = MicroEPEngine.build(e, (rows, cols),
                              placement=PlacementSpec(strategy, seed=3),
                              policy=policy)

    # the legacy path, assembled by hand exactly as call sites used to
    legacy_placement = placement_strategies.get(strategy)(rows, cols, e,
                                                          seed=3)
    legacy_statics = ScheduleStatics.from_placement(legacy_placement)
    legacy_sched = MicroEPScheduler(legacy_statics, sweeps=12,
                                    locality=True, mode=mode,
                                    sequencing="proportional")

    np.testing.assert_array_equal(eng.statics.dev, legacy_statics.dev)
    np.testing.assert_array_equal(eng.statics.slot, legacy_statics.slot)

    rng = np.random.default_rng(0)
    state_e = eng.init_state()
    state_l = legacy_sched.init_state()
    for _ in range(3):   # warm-start threading must match too
        input_eg = jnp.asarray(
            rng.integers(0, 50, size=(e, rows * cols)), jnp.int32)
        out_e = eng.schedule(input_eg, state_e)
        out_l = legacy_sched(input_eg, state_l)
        np.testing.assert_array_equal(np.asarray(out_e.flow),
                                      np.asarray(out_l.flow))
        np.testing.assert_array_equal(np.asarray(out_e.x_int),
                                      np.asarray(out_l.x_int))
        assert float(out_e.max_load) == float(out_l.max_load)
        assert float(out_e.balance) == float(out_l.balance)
        np.testing.assert_array_equal(np.asarray(out_e.solver_state.x),
                                      np.asarray(out_l.solver_state.x))
        state_e, state_l = out_e.solver_state, out_l.solver_state


def test_engine_dispatch_statics_match_legacy():
    eng = MicroEPEngine.build(8, (2, 4), placement="latin")
    legacy = D.build_statics(
        ScheduleStatics.from_placement(latin_placement(2, 4, 8)),
        tokens_per_device=64, top_k=2, capacity_factor=2.0, bm=8)
    got = eng.dispatch_statics(64, 2, capacity_factor=2.0, bm=8)
    np.testing.assert_array_equal(got.exp_of_dev_slot, legacy.exp_of_dev_slot)
    np.testing.assert_array_equal(got.rep_of_dev_slot, legacy.rep_of_dev_slot)
    assert (got.cap, got.bm, got.num_slots, got.c_in) == \
        (legacy.cap, legacy.bm, legacy.num_slots, legacy.c_in)


def test_engine_host_oracle_matches_legacy():
    eng = MicroEPEngine.build(8, (2, 4), placement="latin")
    rng = np.random.default_rng(1)
    input_eg = rng.integers(0, 50, size=(8, 8)).astype(np.int64)
    legacy_sched = MicroEPScheduler(
        ScheduleStatics.from_placement(latin_placement(2, 4, 8)))
    np.testing.assert_allclose(eng.schedule_host(input_eg),
                               legacy_sched.schedule_host(input_eg))


# ------------------------------------------------- architectural grep guard


GUARDED = (re.compile(r"ScheduleStatics\s*\.\s*from_placement\s*\("),
           re.compile(r"MicroEPScheduler\s*\("))
ALLOWED = {  # the only places that may hand-wire the machinery
    REPO / "src" / "repro" / "core",
    REPO / "src" / "repro" / "engine",
    REPO / "tests" / "test_engine.py",   # this file: legacy reference path
}


def _is_allowed(path: pathlib.Path) -> bool:
    return any(path == a or a in path.parents for a in ALLOWED)


def test_no_direct_scheduler_construction_outside_engine():
    """Acceptance guard: every module goes through MicroEPEngine."""
    offenders = []
    for top in ("src", "tests", "examples", "benchmarks"):
        for path in (REPO / top).rglob("*.py"):
            if _is_allowed(path):
                continue
            text = path.read_text()
            for pat in GUARDED:
                for m in pat.finditer(text):
                    line = text[: m.start()].count("\n") + 1
                    offenders.append(f"{path.relative_to(REPO)}:{line} "
                                     f"{m.group(0)!r}")
    assert not offenders, (
        "construct MicroEP machinery via repro.engine.MicroEPEngine, "
        "not by hand:\n" + "\n".join(offenders))


# ------------------------------------------------------- build_runtime shim


def test_build_runtime_config_and_legacy_kwargs_agree():
    from repro.configs import get_config
    from repro.launch import runtime as R
    from repro.launch.mesh import make_local_mesh

    cfg = get_config("olmoe-1b-7b").smoke()
    mesh = make_local_mesh(1, 1)
    dr_new = R.build_runtime(cfg, mesh, RuntimeConfig(
        dtype="float32", impl="ref", remat=False,
        placement=PlacementSpec("latin"),
        policy=SchedulePolicy(mode="microep")))
    dr_old = R.build_runtime(cfg, mesh, dtype=jnp.float32, impl="ref",
                             remat=False, placement_strategy="latin",
                             mode="microep")
    assert dr_new.config == dr_old.config
    np.testing.assert_array_equal(dr_new.placement.table,
                                  dr_old.placement.table)
    # engine-backed schedules agree bit-for-bit across the two builds
    e = dr_new.engine.num_experts
    g = dr_new.engine.num_devices
    input_eg = jnp.asarray(
        np.random.default_rng(2).integers(0, 20, (e, g)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(dr_new.engine.schedule(input_eg).flow),
        np.asarray(dr_old.engine.schedule(input_eg).flow))
    with pytest.raises(ConfigError, match="not both"):
        R.build_runtime(cfg, mesh, RuntimeConfig(), mode="vanilla")
    with pytest.raises(ConfigError, match="unknown build_runtime option"):
        R.build_runtime(cfg, mesh, placement_stragety="latin")
