"""Elastic fleet control + capacity planning tests (FLEET.md,
DESIGN.md §14).

Covers the full subsystem stack:

  * ``FleetConfig`` dict/CLI round-trips and validation;
  * the scaling-policy registry (built-ins, custom registration, the
    unknown-key error listing options);
  * ``FleetController`` lifecycle — admit under pressure, LIFO drain,
    drain-grace completion, the capacity floor, and event-step
    monotonicity on the shared step clock (the ReplacementManager /
    TopologyController decision records ride the same clock —
    regression-tested here);
  * zero-budget placement relaxation (a drained device hosts nothing);
  * the capacity planner — golden sweep pin on the committed mini trace,
    determinism, and the budget-monotonicity property (growing token
    budgets never turns a feasible window infeasible, hypothesis-driven
    via tests/hypothesis_compat.py);
  * drain-under-load at the manager level: no request lost or
    duplicated, FIFO admission (the tests/test_disagg.py harness
    pattern);
  * the serve-loop wiring (``ServingSession(fleet=)``) and the
    multi-host launch scaffolding flags.
"""
import argparse
import json
import pathlib

import numpy as np
import pytest

from hypothesis_compat import (HAVE_HYPOTHESIS, HealthCheck, given,
                               settings, st)

from repro.configs import get_config
from repro.core.lp import budget_feasible, replica_devices
from repro.core.placement import asymmetric_placement
from repro.core.replacement import ReplacementManager
from repro.engine import (ConfigError, DeviceProfile, DisaggConfig,
                          FleetConfig, RegistryError, ServeConfig)
from repro.fleet import (FleetController, FleetCostModel, FleetSignals,
                         StepTimeModel, plan_capacity, register_scaling_policy,
                         scaling_policies, trace_windows)
from repro.launch.mesh import (add_distributed_cli_args,
                               maybe_initialize_distributed)
from repro.serve import BatchManager, Request, ServingSession
from repro.telemetry import LoadTrace

GOLDEN = pathlib.Path(__file__).parent / "golden"


def _req(i, arrival, p=3, g=4, vocab=64):
    rng = np.random.default_rng(i)
    return Request(req_id=i, arrival_step=arrival,
                   prompt=rng.integers(0, vocab, p), max_new=g)


def _signals(step, ctl, *, utilization=0.0, queue=0, busy_above=0):
    return FleetSignals(step=step, utilization=utilization,
                        queue_depth=queue, capacity=ctl.capacity,
                        active_slots=int(utilization * ctl.capacity),
                        busy_above_capacity=busy_above)


# ----------------------------------------------------------- FleetConfig


def test_fleet_config_roundtrips():
    fc = FleetConfig(enabled=True, scaling_policy="queue_depth",
                     min_groups=2, max_groups=5, scale_check_every=8,
                     drain_grace_steps=3, slots_per_group=4,
                     group_profiles=(DeviceProfile(weight=2.0, slots=4),),
                     scale_up_threshold=0.8, scale_down_threshold=0.3,
                     latency_slo_ms=25.0)
    assert FleetConfig.from_dict(fc.to_dict()) == fc
    # CLI round-trip: to_cli_args -> argparse -> from_cli_args
    ap = argparse.ArgumentParser()
    FleetConfig.add_cli_args(ap)
    assert FleetConfig.from_cli_args(ap.parse_args(fc.to_cli_args())) == fc
    # defaults parse to the default config
    assert FleetConfig.from_cli_args(ap.parse_args([])) == FleetConfig()


def test_fleet_config_validation():
    with pytest.raises(ConfigError):
        FleetConfig(min_groups=0)
    with pytest.raises(ConfigError):
        FleetConfig(min_groups=3, max_groups=2)
    with pytest.raises(ConfigError):
        FleetConfig(scale_up_threshold=0.3, scale_down_threshold=0.5)
    with pytest.raises(ConfigError):
        FleetConfig(latency_slo_ms=0.0)
    with pytest.raises(ConfigError):
        FleetConfig.from_dict({"enabled": True, "no_such_knob": 1})


def test_device_profile_rejects_bad_entries():
    # satellite: zero/negative weights and zero-slot fleets must be
    # rejected with an error naming the bad entry
    with pytest.raises(ConfigError, match=r"0@4"):
        DeviceProfile.parse("0@4")
    with pytest.raises(ConfigError, match=r"-2"):
        DeviceProfile.parse("-2")
    with pytest.raises(ConfigError, match=r"1@0"):
        DeviceProfile.parse("1@0")
    with pytest.raises(ConfigError):
        DeviceProfile.parse("nan")


# ------------------------------------------------------ policy registry


def test_scaling_policy_registry():
    assert set(scaling_policies.names()) >= {
        "target_utilization", "queue_depth", "step_latency_slo"}
    with pytest.raises(RegistryError, match="target_utilization"):
        scaling_policies["no_such_policy"]
    with pytest.raises(RegistryError):
        FleetController(FleetConfig(enabled=True,
                                    scaling_policy="no_such_policy"),
                        num_experts=2)

    @register_scaling_policy("always_up_test", override=True)
    def always_up(signals, cfg):
        return 2.0

    ctl = FleetController(
        FleetConfig(enabled=True, scaling_policy="always_up_test",
                    min_groups=1, max_groups=2, scale_check_every=1),
        num_experts=2)
    events = ctl.observe(_signals(1, ctl), 1)
    assert [e["kind"] for e in events] == ["admit"]


def test_step_latency_policy_needs_slo():
    ctl = FleetController(
        FleetConfig(enabled=True, scaling_policy="step_latency_slo",
                    min_groups=1, max_groups=2, scale_check_every=1),
        num_experts=2)
    with pytest.raises(ValueError, match="latency_slo_ms"):
        ctl.observe(_signals(1, ctl), 1)


# -------------------------------------------------------- controller


def _controller(**kw):
    cfg = FleetConfig(enabled=True, scaling_policy="queue_depth",
                      min_groups=kw.pop("min_groups", 1),
                      max_groups=kw.pop("max_groups", 3),
                      slots_per_group=kw.pop("slots_per_group", 2),
                      scale_check_every=kw.pop("scale_check_every", 4),
                      drain_grace_steps=kw.pop("drain_grace_steps", 2),
                      scale_up_threshold=0.9, scale_down_threshold=0.35,
                      **kw)
    return FleetController(cfg, num_experts=4, bytes_per_expert=8)


def test_controller_admit_drain_lifecycle():
    ctl = _controller()
    assert (ctl.num_groups, ctl.capacity) == (1, 2)
    # pressure above threshold on a check step: admit
    ev = ctl.observe(_signals(4, ctl, utilization=1.0, queue=5), 4)
    assert [e["kind"] for e in ev] == ["admit"] and ctl.num_groups == 2
    assert ev[0]["moved_slots"] > 0          # water-filled onto new device
    assert ev[0]["migration_bytes"] == ev[0]["moved_slots"] * 8
    ev = ctl.observe(_signals(8, ctl, utilization=1.0, queue=5), 8)
    assert ctl.num_groups == 3 == ctl.cfg.max_groups
    # at max: pressure is ignored
    assert ctl.observe(_signals(12, ctl, utilization=1.0, queue=9), 12) == []
    # idle: drain starts (LIFO — the last-admitted group departs) but
    # completes only after the grace period with no straggler sequences
    ev = ctl.observe(_signals(16, ctl, utilization=0.1), 16)
    assert [e["kind"] for e in ev] == ["drain"]
    assert ev[0]["group"] == ctl.draining is not None
    assert ctl.active_groups == 2            # admission capacity shrank
    assert ctl.observe(_signals(17, ctl, busy_above=1), 17) == []
    ev = ctl.observe(_signals(19, ctl, busy_above=0), 19)
    assert [e["kind"] for e in ev] == ["drain_complete"]
    assert ctl.num_groups == 2
    s = ctl.summary()
    assert (s["admits"], s["drains"], s["peak_groups"]) == (2, 1, 3)
    steps = [e["step"] for e in s["events"]]
    assert steps == sorted(steps)


def test_controller_capacity_floor_refuses_drain():
    ctl = _controller(min_groups=1, max_groups=2, slots_per_group=2)
    # a 1-group fleet never drains below min_groups
    assert ctl.observe(_signals(4, ctl, utilization=0.0), 4) == []
    assert ctl.num_groups == 1


def test_controller_min_fleet_must_host_experts():
    cfg = FleetConfig(enabled=True, min_groups=1, max_groups=2,
                      group_profiles=(DeviceProfile(slots=2),))
    with pytest.raises(ValueError, match="cannot host"):
        FleetController(cfg, num_experts=8)


def test_controller_event_steps_monotone_with_replacement_clock():
    # regression (satellite 3): fleet events and replacement decision
    # records share one step clock and stay ordered when interleaved
    ctl = _controller(scale_check_every=2)
    from repro.core.placement import vanilla_placement
    from repro.core.replacement import ReplacementConfig
    mgr = ReplacementManager(vanilla_placement(1, 4, 4),
                             ReplacementConfig(check_every=3,
                                               threshold=1.01, seed=0))
    merged, seen = [], None
    rng = np.random.default_rng(0)
    for step in range(24):
        load = rng.uniform(0.1, 10.0, 4)
        merged.extend(ctl.observe(
            _signals(step, ctl, utilization=(1.0 if step < 12 else 0.0),
                     queue=(6 if step < 12 else 0),
                     busy_above=(0 if step % 5 else 1)), step))
        mgr.observe(load, step=step)
        if mgr.last_decision is not None and mgr.last_decision is not seen:
            # a fresh decision record carries the *external* shared step
            assert mgr.last_decision["step"] == step
            seen = mgr.last_decision
    steps = [e["step"] for e in merged]
    assert len(merged) >= 3 and steps == sorted(steps)


# ------------------------------------------- zero-budget placement


def test_asymmetric_placement_zero_budgets():
    loads = np.asarray([5.0, 3.0, 2.0, 1.0])
    budgets = np.asarray([2, 2, 0, 2])        # device 2 drained
    p = asymmetric_placement(1, 4, 4, loads, slot_budgets=budgets)
    table = np.asarray(p.table).reshape(4, -1)
    assert (table[2] < 0).all()               # drained device hosts nothing
    hosted = set(int(x) for x in table[table >= 0])
    assert hosted == {0, 1, 2, 3}             # every expert still placed
    with pytest.raises(ValueError, match=">= 0"):
        asymmetric_placement(1, 4, 4, loads,
                             slot_budgets=np.asarray([2, 2, -1, 2]))
    with pytest.raises(ValueError, match="positive"):
        asymmetric_placement(1, 4, 4, loads,
                             slot_budgets=np.zeros(4, np.int64))


# ------------------------------------------------------------ planner


def test_plan_capacity_golden_and_deterministic():
    tr = LoadTrace.load(str(GOLDEN / "fleet_mini_trace.jsonl"))
    kw = dict(slo_us=10_000.0,
              time_model=StepTimeModel(us_per_token=394.65),
              cost_model=FleetCostModel(), min_groups=1, max_groups=6,
              window=16)
    plan = plan_capacity(tr, **kw)
    golden = json.loads((GOLDEN / "fleet_plan.json").read_text())
    assert json.loads(json.dumps(plan.to_dict(), sort_keys=True)) == golden
    # deterministic given (trace, cost model, SLO)
    assert plan_capacity(tr, **kw).to_dict() == plan.to_dict()
    # the recommendation is cheaper elastic than static and SLO-feasible
    assert plan.best is not None and plan.best["feasible"]
    assert plan.elastic_cost <= plan.static_cost


def test_plan_capacity_infeasible_slo():
    loads = np.full((8, 4), 1e9)
    plan = plan_capacity(loads, slo_us=1.0,
                         time_model=StepTimeModel(us_per_token=100.0),
                         max_groups=2, window=4)
    assert plan.best is None and plan.schedule == []
    assert all(not c["feasible"] for c in plan.sweep)


def test_step_time_model_calibration(tmp_path):
    p = tmp_path / "bench.json"
    p.write_text(json.dumps({"rows": [
        {"bench": "pipeline", "us": 1000.0, "tokens_per_device": 10},
        {"bench": "pipeline", "us": 3000.0, "tokens_per_device": 10},
        {"bench": "other", "us": 1.0, "tokens_per_device": 1},
    ]}))
    tm = StepTimeModel.from_bench(str(p))
    assert tm.us_per_token == pytest.approx(200.0)   # median of 100, 300
    with pytest.raises(ValueError):
        StepTimeModel.from_bench(str(p), bench="missing")
    with pytest.raises(ValueError):
        StepTimeModel(us_per_token=200.0, fixed_us=50.0).token_budget(40.0)


def test_cost_model_parse():
    cm = FleetCostModel.parse("2@4=3.0,1=0.5", default_rate=1.0)
    assert cm.rate(DeviceProfile(weight=2.0, slots=4)) == 3.0
    assert cm.rate(DeviceProfile()) == 0.5
    assert cm.rate(DeviceProfile(weight=7.0)) == 1.0   # default
    with pytest.raises(ValueError, match="profile=rate"):
        FleetCostModel.parse("2@4")
    with pytest.raises(ConfigError, match="0@4"):
        FleetCostModel.parse("0@4=1.0")


def _budget_monotone_body(seed, e, g):
    """Growing per-device token budgets never turns a feasible window
    infeasible, and never increases utilization — the property the
    elastic planner's admit schedule relies on."""
    rng = np.random.default_rng(seed)
    loads = rng.uniform(0.0, 100.0, e)
    from repro.replication import replicated_placement
    # explicit slots: the default requires e % g == 0
    p = replicated_placement(1, g, e, loads=loads, slots=-(-e // g))
    dev = replica_devices(p)
    base = rng.uniform(10.0, 200.0, g)
    ok0, util0 = budget_feasible(loads, dev, g, base)
    grown = base * rng.uniform(1.0, 3.0, g)
    ok1, util1 = budget_feasible(loads, dev, g, grown)
    if ok0:
        assert ok1, "growing budgets broke feasibility"
    if np.isfinite(util0):
        assert util1 <= util0 + 1e-6


_BUDGET_GRID = [(0, 2, 2), (1, 8, 5), (2, 4, 3), (3, 8, 2),
                (4, 5, 5), (5, 3, 4), (6, 8, 3), (7, 6, 2)]


@pytest.mark.parametrize("seed,e,g", _BUDGET_GRID,
                         ids=range(len(_BUDGET_GRID)))
def test_budget_feasibility_monotone_deterministic(seed, e, g):
    _budget_monotone_body(seed, e, g)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=30,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8),
           st.integers(2, 5))
    def test_budget_feasibility_monotone_in_budgets(seed, e, g):
        _budget_monotone_body(seed, e, g)


def test_trace_windows_shapes():
    w = trace_windows(np.ones((10, 3)), 4)
    assert [(s, n) for s, n, _ in w] == [(0, 4), (4, 4), (8, 2)]
    w3 = trace_windows(np.ones((6, 2, 3)), 3)     # [T, L, E] layer-summed
    assert np.allclose(w3[0][2], 2.0)
    with pytest.raises(ValueError):
        trace_windows(np.ones(5), 2)


# ----------------------------------------- drain under load (manager)


def test_drain_under_load_no_loss_fifo():
    """The tests/test_disagg.py harness pattern: a burst admits onto 3
    groups, the controller drains down under falling load, and every
    request still finishes exactly once, admitted in FIFO order."""
    ctl = _controller(min_groups=1, max_groups=3, slots_per_group=2,
                      scale_check_every=2, drain_grace_steps=2)
    width = 3 * 2
    bm = BatchManager(ServeConfig(max_batch=width, max_seq=8))
    bm.set_slot_limit(ctl.capacity)
    reqs = [_req(i, arrival=0) for i in range(9)]
    for r in reqs:
        bm.submit(r)
    finished, admit_order, drained_evs = [], [], []
    for step in range(200):
        if not bm.has_work():
            break
        before = {id(s) for s in bm.slots if s is not None}
        bm.admit_ready(step)
        for s in bm.slots:
            if s is not None and id(s) not in before:
                admit_order.append(s.request.req_id)
        assert bm.n_active <= bm.cfg.max_batch
        finished.extend(bm.observe(np.full(width, 7), step, 0.0))
        queued = sum(1 for r in bm.queue if r.arrival_step <= step)
        evs = ctl.observe(FleetSignals(
            step=step, utilization=bm.n_active / max(ctl.capacity, 1),
            queue_depth=queued, active_slots=bm.n_active,
            capacity=ctl.capacity,
            busy_above_capacity=bm.n_active_above(ctl.capacity)), step)
        drained_evs.extend(evs)
        bm.set_slot_limit(ctl.capacity)
        # shrunk capacity never evicts: stragglers finish in place
        assert bm.n_active_above(ctl.capacity) <= width
    assert not bm.has_work()
    assert sorted(s.request.req_id for s in finished) == list(range(9))
    assert admit_order == sorted(admit_order)       # strict FIFO
    kinds = [e["kind"] for e in drained_evs]
    assert "drain" in kinds and "drain_complete" in kinds


# ------------------------------------------------------ serve wiring


def test_serving_session_fleet_smoke():
    cfg = get_config("paper-gpt-32x1.3b").smoke()
    fc = FleetConfig(enabled=True, min_groups=1, max_groups=3,
                     slots_per_group=2, scale_check_every=4,
                     drain_grace_steps=2, scaling_policy="queue_depth")
    sess = ServingSession(cfg, ServeConfig(max_batch=2, max_seq=16),
                          fleet=fc)
    # compiled width is pinned at the fleet maximum
    assert sess.serve_cfg.max_batch == 6
    reqs = [_req(i, arrival=0) for i in range(8)] \
        + [_req(100 + i, arrival=60 + 4 * i) for i in range(3)]
    rep = sess.run(reqs, max_steps=200)
    ids = sorted(r.req_id for r in rep.records)
    assert ids == sorted(r.req_id for r in reqs)     # no loss, no dupes
    fl = rep.to_dict()["fleet"]
    assert fl["admits"] >= 1 and fl["drains"] >= 1
    steps = [e["step"] for e in fl["events"]]
    assert steps == sorted(steps)
    assert "fleet:" in rep.summary()


def test_serving_session_fleet_disagg_exclusive():
    cfg = get_config("qwen1.5-0.5b").smoke()
    with pytest.raises(ValueError, match="cannot be combined"):
        ServingSession(cfg, ServeConfig(max_batch=2, max_seq=16),
                       disagg=DisaggConfig(enabled=True),
                       fleet=FleetConfig(enabled=True))


def test_serve_report_fleet_absent_by_default():
    # fixed-fleet reports must not grow a "fleet" key (golden bit-identity)
    from repro.serve.loop import ServeReport
    rep = ServeReport(records=[], steps=0, wall_s=0.0, gen_tokens=0,
                      processed_tokens=0, mean_balance=None, overflow=0.0,
                      migrations=0, migrated_bytes=0, rejected=0)
    assert "fleet" not in rep.to_dict()


# ------------------------------------------------------ multi-host


def _dist_args(argv):
    ap = argparse.ArgumentParser()
    add_distributed_cli_args(ap)
    return ap.parse_args(argv)


def test_distributed_flags_default_noop():
    args = _dist_args([])
    assert (args.num_hosts, args.host_id, args.coordinator) == (1, 0, None)
    assert maybe_initialize_distributed(args) is False


def test_distributed_flags_validation():
    with pytest.raises(ValueError, match="--num-hosts"):
        maybe_initialize_distributed(_dist_args(["--num-hosts", "0"]))
    with pytest.raises(ValueError, match="--host-id"):
        maybe_initialize_distributed(_dist_args(
            ["--num-hosts", "2", "--host-id", "2",
             "--coordinator", "h:1234"]))
    with pytest.raises(ValueError, match="--coordinator"):
        maybe_initialize_distributed(_dist_args(
            ["--num-hosts", "2", "--host-id", "0"]))
    with pytest.raises(ValueError, match="--num-hosts > 1"):
        maybe_initialize_distributed(_dist_args(
            ["--coordinator", "h:1234"]))


def test_launch_serve_rejects_fleet_plus_disagg(capsys):
    from repro.launch import serve as serve_cli
    with pytest.raises(SystemExit):
        serve_cli.main(["--arch", "qwen1.5-0.5b", "--smoke",
                        "--fleet", "--disagg"])
    assert "cannot be combined" in capsys.readouterr().err
