"""LPP 1 (paper §5.1): HiGHS oracle vs the in-graph water-filling solver,
Eq. 3 density identity, rounding invariants.  Property-based via hypothesis.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.core.lp import replica_devices, solve_lpp1, solve_lpp4
from repro.core.placement import (latin_placement, max_induced_density,
                                  random_placement, vanilla_placement)
from repro.core.rounding import round_replica_loads
from repro.core.solver_jax import device_loads, solve_replica_loads, water_fill


def _random_instance(rng, rows, cols, k, max_load=200):
    e = cols * k
    p = random_placement(rows, cols, e, seed=int(rng.integers(1 << 30)))
    dev = replica_devices(p)
    loads = rng.integers(0, max_load, size=e).astype(np.float64)
    return p, dev, loads


# ---------------------------------------------------------------- water fill

@given(st.integers(1, 8), st.floats(0.0, 1e4), st.integers(0, 1 << 30))
@settings(max_examples=50, deadline=None)
def test_water_fill_properties(r, budget, seed):
    rng = np.random.default_rng(seed)
    levels = jnp.asarray(rng.uniform(0, 100, r), jnp.float32)
    valid = jnp.asarray(rng.uniform(size=r) < 0.7)
    if not bool(valid.any()):
        valid = valid.at[0].set(True)
    alloc = water_fill(levels, jnp.float32(budget), valid)
    assert float(alloc.min()) >= -1e-4
    np.testing.assert_allclose(float(alloc.sum()), budget, rtol=1e-5,
                               atol=1e-3)
    # equalization: all replicas receiving mass end at the same level,
    # no replica above that level got mass
    lv = np.asarray(levels)
    a = np.asarray(alloc)
    final = lv + a
    active = (a > 1e-3) & np.asarray(valid)
    if active.any() and budget > 1e-3:
        top = final[active]
        assert top.max() - top.min() < 1e-2 * max(top.max(), 1.0)
        idle = (~active) & np.asarray(valid)
        if idle.any():
            assert lv[idle].min() >= top.max() - 1e-2 * max(top.max(), 1.0)


# ------------------------------------------------- solver vs oracle vs Eq. 3

@pytest.mark.parametrize("rows,cols,k,seed", [
    (2, 4, 2, 0), (4, 4, 2, 1), (2, 8, 4, 2), (8, 8, 1, 3), (4, 2, 8, 4),
])
def test_solver_matches_higgs_oracle(rows, cols, k, seed):
    rng = np.random.default_rng(seed)
    p, dev, loads = _random_instance(rng, rows, cols, k)
    res = solve_lpp1(loads, dev, p.num_devices)
    sol = solve_replica_loads(jnp.asarray(loads, jnp.float32),
                              jnp.asarray(dev, jnp.int32),
                              p.num_devices, sweeps=30)
    dl = device_loads(sol.x, jnp.asarray(dev, jnp.int32), p.num_devices)
    # conservation per expert
    np.testing.assert_allclose(np.asarray(sol.x.sum(-1)), loads, rtol=1e-4,
                               atol=1e-2)
    # max device load within 1% + 1 token of the LP optimum
    assert float(dl.max()) <= res.max_load * 1.01 + 1.0


@pytest.mark.parametrize("rows,cols,k,seed", [
    (2, 4, 2, 10), (2, 4, 4, 11), (4, 4, 1, 12),
])
def test_lp_optimum_equals_density_eq3(rows, cols, k, seed):
    """Paper Eq. 3: LP optimum == max induced subgraph density (exact
    bitmask enumeration for <= 16 devices)."""
    rng = np.random.default_rng(seed)
    p, dev, loads = _random_instance(rng, rows, cols, k)
    assert p.num_devices <= 20
    res = solve_lpp1(loads, dev, p.num_devices)
    m_graph = max_induced_density(p, loads)
    np.testing.assert_allclose(res.objective, m_graph, rtol=1e-6, atol=1e-6)


@given(st.integers(0, 1 << 30))
@settings(max_examples=20, deadline=None)
def test_lp_lower_bounds_hypothesis(seed):
    """LP optimum >= mean load (density of the full set) and >= any single
    expert's load / its replica count."""
    rng = np.random.default_rng(seed)
    rows = int(rng.integers(2, 4))
    cols = int(rng.integers(2, 5))
    k = int(rng.integers(1, 3))
    p, dev, loads = _random_instance(rng, rows, cols, k)
    res = solve_lpp1(loads, dev, p.num_devices)
    assert res.objective >= loads.sum() / p.num_devices - 1e-6
    counts = p.replica_count()
    for e in range(len(loads)):
        assert res.objective >= loads[e] / counts[e] - 1e-6


def test_warm_start_converges_faster():
    rng = np.random.default_rng(5)
    p, dev, loads = _random_instance(rng, 4, 8, 2)
    devj = jnp.asarray(dev, jnp.int32)
    oracle = solve_lpp1(loads, dev, p.num_devices).max_load
    # cold with few sweeps vs warm from a perturbed previous solution
    base = solve_replica_loads(jnp.asarray(loads, jnp.float32), devj,
                               p.num_devices, sweeps=30)
    loads2 = loads * rng.uniform(0.9, 1.1, size=loads.shape)
    warm = solve_replica_loads(jnp.asarray(loads2, jnp.float32), devj,
                               p.num_devices, x_init=base.x, sweeps=2)
    cold = solve_replica_loads(jnp.asarray(loads2, jnp.float32), devj,
                               p.num_devices, sweeps=2)
    o2 = solve_lpp1(loads2, dev, p.num_devices).max_load
    warm_max = float(device_loads(warm.x, devj, p.num_devices).max())
    cold_max = float(device_loads(cold.x, devj, p.num_devices).max())
    assert warm_max <= cold_max + 1e-3
    assert warm_max <= o2 * 1.05 + 1.0


# ----------------------------------------------------------------- rounding

@given(st.integers(0, 1 << 30))
@settings(max_examples=30, deadline=None)
def test_rounding_invariants(seed):
    rng = np.random.default_rng(seed)
    e, r = int(rng.integers(1, 10)), int(rng.integers(1, 6))
    valid = rng.uniform(size=(e, r)) < 0.8
    valid[:, 0] = True
    loads = rng.integers(0, 100, size=e)
    # fractional allocation with row sums == loads
    x = rng.uniform(size=(e, r)) * valid
    x = x / np.maximum(x.sum(-1, keepdims=True), 1e-9) * loads[:, None]
    out = round_replica_loads(jnp.asarray(x, jnp.float32),
                              jnp.asarray(loads, jnp.int32),
                              jnp.asarray(valid))
    out = np.asarray(out)
    assert (out >= 0).all()
    assert (out[~valid] == 0).all()
    np.testing.assert_array_equal(out.sum(-1), loads)
    # largest-remainder: each entry within 1 of the fractional value
    assert (np.abs(out - x) <= 1.0 + 1e-5).all()


# ------------------------------------------------------------------- LPP 4

def test_lpp4_reduces_comm_volume():
    """Appendix A.1: with alpha > 0 the comm-aware LP never has a larger
    comm volume than the comp-only LP for the same loads."""
    rng = np.random.default_rng(7)
    p, dev, loads = _random_instance(rng, 2, 4, 2)
    g = p.num_devices
    e = len(loads)
    inputs = rng.multinomial(1, np.ones(g) / g, size=e).astype(np.float64)
    inputs = inputs * loads[:, None]
    r1 = solve_lpp1(loads, dev, g)
    r4 = solve_lpp4(loads, inputs, dev, g, alpha=0.5)
    assert r4.status == 0

    def comm_of(x):
        send = np.zeros(g)
        recv = np.zeros(g)
        for ei in range(e):
            for ri in range(dev.shape[1]):
                gi = dev[ei, ri]
                if gi < 0:
                    continue
                local = min(x[ei, ri], inputs[ei, gi])
                recv[gi] += x[ei, ri] - local
        for gi in range(g):
            inp = inputs[:, gi].sum()
            loc = sum(min(x[ei, ri], inputs[ei, gi])
                      for ei in range(e) for ri in range(dev.shape[1])
                      if dev[ei, ri] == gi)
            send[gi] = inp - loc
        return max(send.max(), recv.max())

    assert comm_of(r4.x) <= comm_of(r1.x) + 1e-6
    # and comp stays within a bounded factor of the optimum
    dl4 = np.zeros(g)
    for ei in range(e):
        for ri in range(dev.shape[1]):
            if dev[ei, ri] >= 0:
                dl4[dev[ei, ri]] += r4.x[ei, ri]
    assert dl4.max() <= r1.max_load * 3 + 1e-6
