"""Disaggregated prefill/decode serving tests (DESIGN.md §13, SERVING.md).

The core is a manager-level simulation harness (`_simulate`) that drives a
prefill :class:`BatchManager`, the bounded :class:`HandoffBuffer` and a
decode :class:`BatchManager` in exactly the per-tick order of
``ServingSession._run_disagg``, checking the boundary invariants at every
phase:

  * admission into the prefill fleet is strict FIFO;
  * neither fleet ever exceeds its slot count or KV token budget;
  * the handoff buffer never exceeds its depth (back-pressure stalls a
    completed prefill in its slot — it is never dropped);
  * no request decodes past its first token before its KV handoff
    completed (first token in prefill, push <= pop <= second token);
  * conservation — every submitted request either finishes or is
    rejected, exactly once.

The harness runs twice: under hypothesis (random traces/geometries; skips
cleanly when the library is absent via tests/hypothesis_compat.py) and
over a deterministic adversarial grid (burst > slots, depth-1 buffer,
single decode slot, oversize requests, finish-in-prefill) so the
invariants stay exercised in minimal environments too.

Fault extensions (RESILIENCE.md): the same harness optionally injects
handoff-transfer failures (the staged item stays in the buffer and
retries after a capped exponential backoff — never dropped) and a
prefill-fleet crash (every in-flight prefill evicted, KV lost, victims
re-enqueued at the FIFO head with retry accounting), in the exact
per-tick order of ``ServingSession._run_disagg`` — both hypothesis-
driven and on a deterministic grid.

End-to-end tests then run the real two-fleet :class:`ServingSession` loop
(dense + MoE smoke), the per-fleet replacement tagging, and the
:class:`DisaggConfig` round-trips.
"""
import argparse

import numpy as np
import pytest

from hypothesis_compat import (HAVE_HYPOTHESIS, HealthCheck, given,
                               settings, st)

from repro.configs import get_config
from repro.engine import ConfigError, DeviceProfile, DisaggConfig, ServeConfig
from repro.resilience import (FaultEvent, FaultInjector, FaultPlan,
                              RetryTracker, transfer_backoff)
from repro.serve import (BatchManager, HandoffBuffer, HandoffItem, Request,
                         ServingSession, replay_trace)

# ------------------------------------------------- simulation harness


def _req(i, arrival, p, g, vocab=64):
    rng = np.random.default_rng(i)
    return Request(req_id=i, arrival_step=arrival,
                   prompt=rng.integers(0, vocab, p), max_new=g)


def _check_budgets(bm: BatchManager):
    assert bm.n_active <= bm.cfg.max_batch
    assert 0 <= bm.reserved_tokens <= bm.cfg.budget_tokens


def _simulate(arrivals, pf_slots, dc_slots, depth, max_seq,
              eos_token=None, max_steps=2000, *,
              transfer_fail_steps=(), transfer_fail_rate=0.0,
              fault_seed=0, backoff=(2, 5), crash_step=None,
              max_retries=10 ** 6):
    """Drive the two fleets + buffer through a whole trace in the exact
    per-tick order of ``ServingSession._run_disagg`` (sampled token is a
    constant 7), asserting every boundary invariant along the way.
    Returns per-request lifecycle stats for the caller's own asserts.

    Fault knobs (RESILIENCE.md): ``transfer_fail_steps`` / ``_rate``
    fail handoff-transfer attempts (the staged item backs off
    ``transfer_backoff(retries, *backoff)`` steps and retries — never
    dropped); ``crash_step`` evicts every in-flight prefill at that step
    (KV lost) and re-enqueues the victims at the FIFO head with
    ``RetryTracker(max_retries)`` accounting."""
    pf_cfg = ServeConfig(max_batch=pf_slots, max_seq=max_seq,
                         eos_token=eos_token)
    dc_cfg = ServeConfig(max_batch=dc_slots, max_seq=max_seq,
                         eos_token=eos_token)
    pf = BatchManager(pf_cfg, role="prefill")
    dc = BatchManager(dc_cfg, role="decode")
    buf = HandoffBuffer(depth)
    injector = None
    if transfer_fail_steps or transfer_fail_rate > 0:
        injector = FaultInjector(FaultPlan(
            events=tuple(FaultEvent(at_step=s, kind="transfer_fail")
                         for s in transfer_fail_steps),
            transfer_fail_rate=transfer_fail_rate, seed=fault_seed))
    tracker = RetryTracker(max_retries)
    reqs = [_req(i, a, p, g) for i, (a, p, g) in enumerate(arrivals)]
    submitted = {r.req_id for r in reqs}
    for r in sorted(reqs, key=lambda r: (r.arrival_step, r.req_id)):
        pf.submit(r)
    rejected = {r.req_id for r in pf.rejected}

    admit_order = []                   # req ids in prefill-admission order
    finished = {}                      # req_id -> ActiveSeq
    push_step = {}
    pop_step = {}
    token_steps = {}                   # req_id -> step of each token
    stalls = 0
    transfer_failures = 0
    crash_victims = []
    step = 0
    while (pf.has_work() or dc.has_work() or len(buf)) \
            and step < max_steps:
        if pf.n_active == 0 and dc.n_active == 0 and not len(buf):
            nxt = pf.next_arrival_step()
            if nxt is not None and nxt > step:
                step = nxt
        # 0. unplanned prefill-fleet crash: every in-flight prefill loses
        # its KV; victims re-enqueue at the FIFO head in arrival order
        if crash_step is not None and step == crash_step:
            victims = pf.evict_range(0, pf_slots)
            vr = sorted((v.request for v in victims),
                        key=lambda r: (r.arrival_step, r.req_id))
            retry, _failed = tracker.account(vr)
            pf.requeue_front(retry)
            crash_victims += [r.req_id for r in vr]
            _check_budgets(pf)
        # 1. drain staged transfers into free decode slots
        while True:
            item = buf.peek()
            if item is None:
                break
            if item.next_attempt_step > step:
                break                  # backing off after a failed attempt
            if injector is not None:
                if not dc.can_admit_transfer(item.seq):
                    break              # no attempt: no fault verdict drawn
                if injector.transfer_fails(step):
                    # failed in flight: stays staged, capped exponential
                    # backoff before the retry — never dropped
                    item.retries += 1
                    transfer_failures += 1
                    item.next_attempt_step = step + transfer_backoff(
                        item.retries, *backoff)
                    break
            slot = dc.admit_transfer(item.seq, step)
            if slot is None:
                break
            buf.pop()
            # no decode before the handoff completed
            assert item.seq.request.req_id in push_step
            pop_step[item.seq.request.req_id] = step
            _check_budgets(dc)
        # 2. admit arrivals into prefill slots, strict FIFO.  The queue
        # stays globally sorted by (arrival, id) even across a crash —
        # head-of-queue requeue preserves it — so head-only admission is
        # FIFO among the requests actually waiting.
        q = [(r.arrival_step, r.req_id) for r in pf.queue]
        assert q == sorted(q)
        before = {id(s) for s in pf.active}
        pf.admit_ready(step)
        admit_order += sorted(
            (s for s in pf.active if id(s) not in before),
            key=lambda s: s.request.req_id)
        admit_order_ids = [s.request.req_id for s in admit_order]
        if crash_step is None:
            # (a re-admitted crash victim legitimately lands after later
            # arrivals admitted pre-crash, so this only holds crash-free)
            assert admit_order_ids == sorted(admit_order_ids)
        _check_budgets(pf)
        # 3. step both fleets (constant sampled token)
        for bm in (pf, dc):
            toks, active = bm.next_tokens()
            if not active.any():
                continue
            pre = {s.request.req_id: len(s.tokens) for s in bm.active}
            sampled = np.full(bm.cfg.max_batch, 7)
            fins = bm.observe(sampled, step, 0.0)
            for s in fins:
                assert s.request.req_id not in finished   # no duplicates
                finished[s.request.req_id] = s
            for s in list(bm.active) + fins:
                if len(s.tokens) > pre.get(s.request.req_id, 0):
                    token_steps.setdefault(
                        s.request.req_id, []).append(step)
            _check_budgets(bm)
        # a parked sequence never grows past its first token in prefill
        for s in pf.active:
            assert len(s.tokens) <= 1
        # 4. stage completed prefills while the buffer has space
        for s in pf.take_handoff_ready():
            if buf.full:
                break
            assert buf.push(HandoffItem(seq=s, push_step=step))
            push_step[s.request.req_id] = step
            pf.release(s)
        assert len(buf) <= depth
        stalls += len(pf.take_handoff_ready())
        step += 1

    assert step < max_steps, "two-fleet loop failed to drain"
    failed = {r.req_id for r in tracker.failed}
    # conservation: finished / rejected / explicitly-failed partition the
    # submitted set — nothing lost, nothing duplicated
    assert set(finished) | rejected | failed == submitted
    assert not (set(finished) & rejected)
    assert not (set(finished) & failed) and not (rejected & failed)
    for r in reqs:
        if r.req_id in rejected or r.req_id in failed:
            continue
        s = finished[r.req_id]
        n = len(s.tokens)
        assert n == r.max_new or (eos_token is not None
                                  and s.tokens[-1] == eos_token)
        if r.req_id in pop_step:
            # first token in prefill, then push <= pop, decode after
            assert s.first_token_step <= push_step[r.req_id] \
                <= pop_step[r.req_id]
            if n > 1:
                steps_after = [t for t in token_steps.get(r.req_id, [])
                               if t >= pop_step[r.req_id]]
                assert len(steps_after) == n - 1
        else:
            # never transferred: must have finished inside prefill
            assert n == 1
    assert buf.transferred == len(pop_step)
    assert buf.peak <= depth
    return {"finished": finished, "rejected": rejected, "failed": failed,
            "admit_order": [s.request.req_id for s in admit_order],
            "stalls": stalls, "buffer": buf, "steps": step,
            "transfer_failures": transfer_failures,
            "crash_victims": crash_victims}


# ------------------------------------------------- property-based suite


def _gaps_to_arrivals(gaps):
    t = 0
    arrivals = []
    for gap, p, g in gaps:
        t += gap
        arrivals.append((t, p, g))
    return arrivals


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(gaps=st.lists(st.tuples(st.integers(0, 3), st.integers(1, 8),
                                   st.integers(1, 6)),
                         min_size=0, max_size=16),
           pf_slots=st.integers(1, 4),
           dc_slots=st.integers(1, 3),
           depth=st.integers(1, 3),
           max_seq=st.integers(4, 12))
    def test_disagg_invariants_property(gaps, pf_slots, dc_slots, depth,
                                        max_seq):
        """Random traces x geometries: every boundary invariant holds and
        the loop always drains (the _simulate harness asserts them all)."""
        _simulate(_gaps_to_arrivals(gaps), pf_slots, dc_slots, depth,
                  max_seq)


def _unified_manager_body(gaps, slots, kv_budget):
    """Co-located manager under random traffic: FIFO admission, budgets
    respected, conservation."""
    cfg = ServeConfig(max_batch=slots, max_seq=8,
                      kv_budget=max(kv_budget, 8))
    bm = BatchManager(cfg)
    t = 0
    reqs = []
    for i, (gap, p, g) in enumerate(gaps):
        t += gap
        reqs.append(_req(i, t, p, g))
    for r in reqs:
        bm.submit(r)
    finished = set()
    admit_order = []
    step = 0
    while bm.has_work() and step < 2000:
        if bm.n_active == 0:
            nxt = bm.next_arrival_step()
            if nxt is not None and nxt > step:
                step = nxt
        before = {id(s) for s in bm.active}
        bm.admit_ready(step)
        admit_order += sorted((s.request.req_id for s in bm.active
                               if id(s) not in before))
        _check_budgets(bm)
        bm.next_tokens()
        for s in bm.observe(np.full(cfg.max_batch, 7), step, 0.0):
            assert s.request.req_id not in finished
            finished.add(s.request.req_id)
        step += 1
    assert step < 2000
    assert admit_order == sorted(admit_order)              # strict FIFO
    assert finished | {r.req_id for r in bm.rejected} == \
        {r.req_id for r in reqs}


_UNIFIED_GRID = [
    # (gaps [(gap, prompt, gen)], slots, kv_budget)
    ([(0, 3, 2)], 1, 8),                          # single request
    ([(0, 3, 3)] * 6, 2, 12),                     # burst > slots
    ([(1, 4, 2)] * 5, 4, 8),                      # steady, tight kv
    ([(0, 8, 6)], 2, 40),                         # oversize -> rejected
    ([(2, 2, 1)] * 8, 3, 16),                     # short gens, gaps
    ([(0, 1, 5), (0, 5, 1), (3, 4, 4)], 2, 10),   # mixed shapes
]


@pytest.mark.parametrize("gaps,slots,kv_budget", _UNIFIED_GRID,
                         ids=range(len(_UNIFIED_GRID)))
def test_unified_manager_invariants_deterministic(gaps, slots, kv_budget):
    _unified_manager_body(gaps, slots, kv_budget)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(gaps=st.lists(st.tuples(st.integers(0, 3), st.integers(1, 8),
                                   st.integers(1, 6)),
                         min_size=1, max_size=16),
           slots=st.integers(1, 4),
           kv_budget=st.integers(8, 40))
    def test_unified_manager_invariants_property(gaps, slots, kv_budget):
        _unified_manager_body(gaps, slots, kv_budget)


# ------------------------------------- deterministic adversarial grid

_GRID = [
    # (arrivals [(step, prompt, gen)], pf, dc, depth, max_seq, eos)
    ([], 2, 2, 2, 8, None),                       # empty trace
    ([(0, 3, 2)], 1, 1, 1, 8, None),              # single request
    ([(0, 3, 3)] * 8, 2, 1, 1, 8, None),          # burst > total slots
    ([(0, 2, 1)] * 4, 2, 1, 1, 8, None),          # finish inside prefill
    ([(0, 2, 4)] * 6, 3, 1, 1, 8, None),          # depth-1 back-pressure
    ([(0, 9, 4), (0, 3, 2)], 2, 2, 2, 8, None),   # oversize -> rejected
    ([(i, 4, 3) for i in range(10)], 2, 2, 1, 8, None),   # steady stream
    ([(0, 3, 5)] * 5, 4, 1, 2, 10, 7),            # EOS (= sampled token)
]


@pytest.mark.parametrize("arrivals,pf,dc,depth,max_seq,eos",
                         _GRID, ids=range(len(_GRID)))
def test_disagg_invariants_deterministic(arrivals, pf, dc, depth,
                                         max_seq, eos):
    """The same invariant harness over an adversarial grid — runs even
    without hypothesis installed."""
    out = _simulate(arrivals, pf, dc, depth, max_seq, eos_token=eos)
    n_fit = sum(1 for _, p, g in arrivals if p + g <= max_seq)
    assert len(out["finished"]) == n_fit
    assert len(out["rejected"]) == len(arrivals) - n_fit


# ------------------------------------------------- fault extensions


def _fault_invariants_body(gaps, pf_slots, dc_slots, depth, rate,
                           crash_step, fault_seed):
    """Random traces x geometries x faults (transfer-failure rates and a
    prefill-fleet crash): every boundary invariant still holds, the loop
    still drains, and conservation covers the explicit failed state."""
    arrivals = _gaps_to_arrivals(gaps)
    out = _simulate(arrivals, pf_slots, dc_slots, depth, max_seq=12,
                    transfer_fail_rate=rate, fault_seed=fault_seed,
                    backoff=(1, 3), crash_step=crash_step)
    assert set(out["finished"]) | out["rejected"] | out["failed"] == \
        set(range(len(arrivals)))


_FAULT_GRID = [
    # (gaps, pf, dc, depth, rate, crash_step, fault_seed)
    ([(0, 3, 2)] * 4, 2, 1, 1, 0.0, None, 0),     # fault-free baseline
    ([(0, 3, 2)] * 4, 2, 1, 1, 0.5, None, 1),     # heavy transfer loss
    ([(0, 4, 3)] * 6, 1, 1, 1, 0.3, None, 2),     # loss + depth-1 stall
    ([(1, 3, 2)] * 5, 2, 2, 2, 0.0, 0, 3),        # crash before admit
    ([(0, 3, 2)] * 5, 2, 2, 2, 0.0, 3, 4),        # mid-flight crash
    ([(0, 5, 4)] * 4, 3, 1, 1, 0.4, 5, 5),        # loss AND crash
]


@pytest.mark.parametrize("gaps,pf,dc,depth,rate,crash,seed", _FAULT_GRID,
                         ids=range(len(_FAULT_GRID)))
def test_disagg_fault_invariants_deterministic(gaps, pf, dc, depth, rate,
                                               crash, seed):
    _fault_invariants_body(gaps, pf, dc, depth, rate, crash, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(gaps=st.lists(st.tuples(st.integers(0, 3), st.integers(1, 8),
                                   st.integers(1, 6)),
                         min_size=1, max_size=12),
           pf_slots=st.integers(1, 4),
           dc_slots=st.integers(1, 3),
           depth=st.integers(1, 3),
           rate=st.floats(0.0, 0.5),
           crash_step=st.one_of(st.none(), st.integers(0, 10)),
           fault_seed=st.integers(0, 9))
    def test_disagg_fault_invariants_property(gaps, pf_slots, dc_slots,
                                              depth, rate, crash_step,
                                              fault_seed):
        _fault_invariants_body(gaps, pf_slots, dc_slots, depth, rate,
                               crash_step, fault_seed)


def test_disagg_transfer_failures_retry_never_drop():
    """Scripted transfer failures: the staged item backs off and retries,
    every request still finishes exactly once."""
    out = _simulate([(0, 3, 4)] * 6, 3, 1, 2, 8,
                    transfer_fail_steps=(1, 2, 3), backoff=(1, 3))
    assert out["transfer_failures"] >= 1
    assert len(out["finished"]) == 6 and not out["failed"]
    assert out["buffer"].peak <= 2


def test_disagg_prefill_crash_preserves_invariants():
    """A prefill-fleet crash mid-burst: victims lose their KV, re-enqueue
    at the FIFO head, and every request still finishes exactly once
    (conservation, ordering, and buffer-depth asserts run per-tick
    inside the harness)."""
    out = _simulate([(0, 4, 3)] * 6 + [(2, 3, 2)] * 2, 3, 2, 2, 8,
                    crash_step=2)
    assert out["crash_victims"], "crash must catch in-flight prefills"
    assert len(out["finished"]) == 8 and not out["failed"]
    assert not out["rejected"]


def test_disagg_crash_retry_budget_exhausts_to_failed():
    """max_retries=0: crash victims move to the explicit failed terminal
    state instead of re-enqueueing — never silently lost."""
    out = _simulate([(0, 4, 3)] * 4, 2, 1, 1, 8, crash_step=1,
                    max_retries=0)
    assert out["crash_victims"]
    assert out["failed"] == set(out["crash_victims"])
    assert set(out["finished"]) | out["failed"] == set(range(4))


def test_disagg_backpressure_stalls_never_drops():
    """Depth-1 buffer + 1 decode slot under a burst: completed prefills
    stall in their slots (counted), every request still finishes."""
    out = _simulate([(0, 2, 4)] * 6, 3, 1, 1, 8)
    assert out["stalls"] > 0
    assert out["buffer"].peak == 1
    assert len(out["finished"]) == 6


def test_handoff_buffer_bounds_and_counters():
    buf = HandoffBuffer(2)
    with pytest.raises(ValueError):
        HandoffBuffer(0)
    items = [HandoffItem(seq=None, kv_bytes=10, push_step=s)
             for s in range(3)]
    assert buf.push(items[0]) and buf.push(items[1])
    assert buf.full and not buf.push(items[2])             # back-pressure
    assert len(buf) == buf.peak == 2
    assert buf.bytes_total == 20                           # staged only
    assert buf.pop() is items[0] and buf.pop() is items[1]  # FIFO
    assert buf.transferred == 2 and len(buf) == 0


def test_decode_manager_refuses_raw_submit():
    bm = BatchManager(ServeConfig(max_batch=2, max_seq=8), role="decode")
    with pytest.raises(ValueError):
        bm.submit(_req(0, 0, 2, 2))
    with pytest.raises(ValueError):
        BatchManager(ServeConfig(), role="verify")


# ---------------------------------------------------- end-to-end loop


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "paper-gpt-32x1.3b"])
def test_disagg_serving_loop_end_to_end(arch):
    """The real two-fleet ServingSession: all requests served, per-request
    budgets honored, handoff stats reported, deterministic re-runs."""
    cfg = get_config(arch).smoke()
    arrivals = [(0, 6, 5), (0, 4, 3), (2, 5, 4), (7, 6, 6), (9, 3, 1)]
    dg = DisaggConfig(enabled=True, prefill_slots=3, decode_slots=2,
                      handoff_depth=2)
    make = lambda: ServingSession(
        cfg, ServeConfig(max_batch=3, max_seq=24), seed=0, disagg=dg)
    rep = make().run(replay_trace(arrivals, vocab=cfg.vocab, seed=11))
    assert len(rep.records) == 5 and rep.rejected == 0
    for r, (_, _, g) in zip(sorted(rep.records, key=lambda r: r.req_id),
                            arrivals):
        assert r.n_generated == g
        assert r.arrival_step <= r.admit_step <= r.first_token_step \
            <= r.finish_step
    d = rep.to_dict()
    assert d["disagg"]["prefill_slots"] == 3
    assert d["disagg"]["decode_slots"] == 2
    # the max_new=1 request finishes inside prefill, never transfers
    assert d["disagg"]["transferred"] == 4
    assert d["disagg"]["handoff_peak"] <= 2
    assert d["disagg"]["handoff_bytes"] > 0
    assert "disagg:" in rep.summary()
    if cfg.moe:
        assert d["disagg"]["prefill_balance"] >= 1.0
        assert d["disagg"]["decode_balance"] >= 1.0
    else:
        assert rep.mean_balance is None
    rep2 = make().run(replay_trace(arrivals, vocab=cfg.vocab, seed=11))
    assert [r.tokens for r in rep.records] == \
        [r.tokens for r in rep2.records]


def test_disagg_fleet_tagged_replacement_records():
    """Satellite fix: per-fleet replacement hooks tag their decision
    records with the fleet that fired; co-located records stay untagged."""
    from repro.serve.replacement import ServeReplacement
    from repro.core.placement import vanilla_placement

    sc = ServeConfig(max_batch=2, max_seq=16, replacement=True,
                     repl_check_every=1, repl_threshold=1.0)
    skew = np.array([30.0, 1.0, 1.0, 1.0])

    def hook(fleet):
        return ServeReplacement(vanilla_placement(1, 1, 4), sc, 128,
                                fleet=fleet)

    for fleet in ("prefill", "decode"):
        h = hook(fleet)
        for _ in range(4):
            h.observe(skew, step=0)
        assert h.events, "skewed loads must leave decision records"
        assert all(e["fleet"] == fleet for e in h.events)
    h = hook(None)
    for _ in range(4):
        h.observe(skew, step=0)
    assert h.events and all("fleet" not in e for e in h.events)


def test_disagg_session_builds_per_fleet_hooks():
    cfg = get_config("paper-gpt-32x1.3b").smoke()
    sc = ServeConfig(max_batch=2, max_seq=16, replacement=True,
                     repl_check_every=2, repl_threshold=1.05)
    dg = DisaggConfig(enabled=True, prefill_slots=2, decode_slots=1,
                      handoff_depth=2)
    sess = ServingSession(cfg, sc, seed=0, disagg=dg)
    assert sess.replacement is None                   # no co-located hook
    assert sess.fleets["prefill"].replacement.fleet == "prefill"
    assert sess.fleets["decode"].replacement.fleet == "decode"
    rep = sess.run(replay_trace([(0, 5, 4), (1, 4, 3), (2, 5, 3)],
                                vocab=cfg.vocab, seed=7))
    assert len(rep.records) == 3
    for e in rep.to_dict()["migration_events"]:
        assert e["fleet"] in ("prefill", "decode")


# -------------------------------------------------------- DisaggConfig


def test_disagg_config_validation():
    with pytest.raises(ConfigError):
        DisaggConfig(prefill_slots=0)
    with pytest.raises(ConfigError):
        DisaggConfig(decode_slots=-1)
    with pytest.raises(ConfigError):
        DisaggConfig(handoff_depth=0)
    assert DisaggConfig().enabled is False


def test_disagg_config_dict_roundtrip():
    dg = DisaggConfig(enabled=True, prefill_slots=4, decode_slots=2,
                      handoff_depth=3,
                      prefill_profiles=(DeviceProfile(weight=2.0),
                                        DeviceProfile(weight=1.0)),
                      decode_profiles=[{"weight": 1.0, "slots": 8}])
    back = DisaggConfig.from_dict(dg.to_dict())
    assert back == dg
    assert back.decode_profiles[0].slots == 8
    assert DisaggConfig.from_dict(DisaggConfig().to_dict()) == \
        DisaggConfig()


def test_disagg_config_cli_roundtrip():
    dg = DisaggConfig(enabled=True, prefill_slots=4, decode_slots=2,
                      handoff_depth=3,
                      prefill_profiles=(DeviceProfile(weight=2.0),
                                        DeviceProfile(weight=1.0)))
    ap = argparse.ArgumentParser()
    DisaggConfig.add_cli_args(ap)
    args = ap.parse_args(dg.to_cli_args())
    assert DisaggConfig.from_cli_args(args) == dg
    # defaults parse back to the default config
    assert DisaggConfig.from_cli_args(ap.parse_args([])) == DisaggConfig()
