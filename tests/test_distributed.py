"""Multi-device tests via subprocess (the main pytest process stays at one
CPU device; --xla_force_host_platform_device_count is per-process).

Each check is a standalone script executed with 8 fake devices on a
(data=2, model=4) mesh:
  * distributed train step == single-device reference (loss, grads)
  * MicroEP dispatch conservation under real all_to_all
  * EDP gradient sync (sync.py ppermute path) == table scatter-add
  * distributed flash-decode (seq-sharded KV) == single-device attention
"""
import os
import subprocess
import sys

import pytest

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def run(script: str):
    r = subprocess.run([sys.executable, "-c", script], env=ENV,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_distributed_step_matches_local():
    run("""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch import runtime as R
from repro.train.loop import TrainState, make_train_step
from repro.optim.adamw import adamw_init
from repro.data.synthetic import SyntheticLM
from repro.models import decoder as dec

assert len(jax.devices()) == 8
cfg = get_config("paper-gpt-32x1.3b").smoke()
mesh = make_local_mesh(2, 4)
# capacity_factor 4: at toy scale (16 tokens/device) the per-(src,dst)
# chunk is 8 rows at cf=2 and integer spikes overflow; production scales
# (thousands of tokens/device) keep cf=2 overflow-free (dry-run configs)
dr = R.build_runtime(cfg, mesh, dtype=jnp.float32, impl="ref", remat=False,
                     capacity_factor=4.0)
key = jax.random.PRNGKey(0)
master = dec.init_params(key, cfg, jnp.float32)
ts = TrainState(master=master, opt=adamw_init(master), solver=dr.init_solver(),
                step=jnp.zeros((), jnp.int32))
step = jax.jit(R.make_train_fn(dr, n_micro=2))
b = SyntheticLM(vocab=cfg.vocab, seq_len=32, batch=8, seed=1).batch_at(0)
ts2, m = step(ts, b)

ts_ref = TrainState(master=master, opt=adamw_init(master),
                    solver=dec.init_solver_states(cfg, 1),
                    step=jnp.zeros((), jnp.int32))
step_ref = jax.jit(make_train_step(cfg, n_micro=2))
ts_ref2, m_ref = step_ref(ts_ref, b)
dl = abs(float(m["loss"]) - float(m_ref["loss"]))
assert dl < 2e-4, (float(m["loss"]), float(m_ref["loss"]))
assert float(m["overflow"]) == 0.0, m
# optimizer moments match closely (pre-Adam-rescaling comparison)
import jax.tree_util as jtu
for a, b_ in zip(jtu.tree_leaves(ts2.opt.mu), jtu.tree_leaves(ts_ref2.opt.mu)):
    import numpy as np
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-2, atol=2e-4)
print("OK")
""")


def test_vanilla_ep_baseline_runs_and_balances_worse():
    run("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch import runtime as R
from repro.models import decoder as dec
from repro.moe.router import zipf_gating

cfg = get_config("paper-gpt-32x1.3b").smoke()
# 8 experts over 4 cols -> k=2 slots (intersecting EDP groups)
import dataclasses
cfg = dataclasses.replace(cfg, num_experts=8)
mesh = make_local_mesh(2, 4)
key = jax.random.PRNGKey(0)
bal = {}
for mode in ("microep", "vanilla"):
    strat = "latin" if mode == "microep" else "vanilla"
    dr = R.build_runtime(cfg, mesh, dtype=jnp.float32, impl="ref",
                         remat=False, mode=mode, placement_strategy=strat,
                         capacity_factor=4.0)
    master = dec.init_params(key, cfg, jnp.float32)
    params = dr.hooks.to_working(master)
    n = 512
    x = jax.random.normal(key, (n, cfg.d_model)) * 0.5
    # skewed synthetic routing (Zipf s=1.0)
    r = zipf_gating(jax.random.fold_in(key, 1), n, cfg.num_experts,
                    cfg.top_k, s=1.0)

    def apply(p_moe, x):
        # use the island directly with the synthetic router via monkeypatch
        out, metrics, _ = dr.rt.moe_apply(p_moe, x, None)
        return metrics

    # patch gating inside by binding router output: route via moe_apply's
    # own gate on a crafted input is hard - instead measure schedule balance
    # through the metrics of a real call (router at init is ~uniform), then
    # through the scheduler directly for the skewed load:
    sched = dr.engine.scheduler
    loads = np.asarray(jax.random.categorical(
        jax.random.fold_in(key, 2),
        jnp.log(jnp.arange(1, cfg.num_experts + 1.) ** -1.0)[None].repeat(n, 0)))
    cnt = np.zeros((cfg.num_experts, 8), np.int32)
    for i, e in enumerate(loads):
        cnt[e, i % 8] += 1
    out = sched(jnp.asarray(cnt))
    bal[mode] = float(out.balance)
print(bal)
assert bal["microep"] <= bal["vanilla"] + 1e-6
# 8 devices x 8 experts (k=2 slots) at Zipf s=1.0: MicroEP stays well
# below vanilla's ~2.28x.  The HiGHS LP optimum for this exact load draw
# is 1.539x (engine.schedule_host) — the in-graph solver + rounding land
# on 1.547x — so assert a band just above the true optimum.
assert bal["microep"] < 1.6
print("OK")
""")


def test_edp_grad_sync_ppermute_matches_scatter():
    """sync.py's explicit ppermute grad sync == scatter-add through the
    placement table (the GSPMD path used by the training loop)."""
    run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core.placement import latin_placement
from repro.moe.sync import (build_sync_plan, working_grads_to_canonical,
                            canonical_to_working)
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(2, 4)
p = latin_placement(2, 4, 8)     # 8 experts over 2x4 devices, k=2 slots
plan = build_sync_plan(p)
k_c = plan.k_canonical
rng = np.random.default_rng(0)
g_work = rng.normal(size=(2, 4, p.slots, 3, 5)).astype(np.float32)

canon_ref = np.zeros((8, 3, 5), np.float32)
for d in range(2):
    for m in range(4):
        for s in range(p.slots):
            canon_ref[p.table[d, m, s]] += g_work[d, m, s]

send = jnp.asarray(plan.send_slot)[:, :, None]   # [n_match, G, 1]
recv = jnp.asarray(plan.recv_slot)[:, :, None]
own = jnp.asarray(plan.self_slot)[:, None, :]    # [G, 1, k]

def per_device(gw, send_slot, recv_slot, self_slot):
    canon = working_grads_to_canonical(
        plan, gw[0, 0], send_slot[:, 0, 0], recv_slot[:, 0, 0],
        self_slot[0, 0], ("data", "model"))
    canon = jax.lax.psum(canon, "data")          # finish the EDP reduce
    work = canonical_to_working(
        plan, canon, send_slot[:, 0, 0], recv_slot[:, 0, 0],
        self_slot[0, 0], ("data", "model"))
    return canon[None, None], work[None, None]

canon_out, work_out = shard_map(per_device, mesh=mesh,
    in_specs=(P("data", "model"), P(None, ("data", "model"), None),
              P(None, ("data", "model"), None),
              P(("data", "model"), None, None)),
    out_specs=(P("data", "model"), P("data", "model")),
    check_rep=False)(jnp.asarray(g_work), send, recv, own)

canon_out = np.asarray(canon_out)   # [D, M, k, 3, 5]
for d in range(2):
    for c in range(4):
        for j in range(k_c):
            np.testing.assert_allclose(canon_out[d, c, j],
                                       canon_ref[c * k_c + j],
                                       rtol=1e-5, atol=1e-5)
# redistribute (canonical -> working) lands each slot's expert params
work_out = np.asarray(work_out)
for d in range(2):
    for m in range(4):
        for s in range(p.slots):
            np.testing.assert_allclose(work_out[d, m, s],
                                       canon_ref[p.table[d, m, s]],
                                       rtol=1e-5, atol=1e-5)
print("OK")
""")


def test_seq_sharded_flash_decode_matches_local():
    run("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.models.layers.attention import (AttnConfig, init_attention,
                                           decode_attention, init_kv_cache,
                                           attention)
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(8, 1)
cfg = AttnConfig(d_model=32, num_heads=2, num_kv_heads=2, head_dim=16)
key = jax.random.PRNGKey(0)
p = init_attention(key, cfg)
t = 64
x = jax.random.normal(jax.random.fold_in(key, 1), (1, t, 32)) * 0.5
pos = jnp.arange(t)[None]
ref = attention(p, cfg, x, pos)

# decode against a cache sharded over 'data' on the sequence axis
cache = init_kv_cache(cfg, 1, t, seq_shards=8)  # local view builder
# build global cache then let shard_map split it
k_all = jnp.zeros((1, 2, t, 16)); v_all = jnp.zeros((1, 2, t, 16))

def step(p, x_t, k_all, v_all, length):
    def inner(p, x_t, k_loc, v_loc, length):
        from repro.models.layers.attention import KVCache
        cache = KVCache(k=k_loc, v=v_loc, length=length)
        o, c = decode_attention(p, cfg, x_t, cache, seq_axis="data")
        return o, c.k, c.v
    return shard_map(inner, mesh=mesh,
        in_specs=(P(), P(), P(None, None, "data", None),
                  P(None, None, "data", None), P()),
        out_specs=(P(), P(None, None, "data", None),
                   P(None, None, "data", None)), check_rep=False)(
        p, x_t, k_all, v_all, length)

outs = []
for i in range(t):
    o, k_all, v_all = step(p, x[:, i:i+1], k_all, v_all, jnp.asarray(i))
    outs.append(o[:, 0])
got = jnp.stack(outs, axis=1)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-4, atol=2e-4)
print("OK")
""")
