"""Substrate tests: data pipeline, optimizer, schedule, checkpointing,
training loop behaviour (loss decreases, warm-started solver threading)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (latest_checkpoint, restore_checkpoint,
                              save_checkpoint)
from repro.configs import get_config
from repro.data.synthetic import SyntheticLM, make_batch, zipf_expert_loads
from repro.models import decoder as dec
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine
from repro.train.loop import TrainState, make_train_step


def test_synthetic_lm_deterministic_and_learnable_structure():
    d = SyntheticLM(vocab=64, seq_len=16, batch=4, seed=3, noise=0.0)
    a = d.batch_at(5)
    b = d.batch_at(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    # zero-noise stream follows an affine map: consecutive-token pairs
    # repeat deterministically per sequence
    tok = np.asarray(a["tokens"])
    for r in range(4):
        pairs = {}
        for i in range(15):
            prev, nxt = int(tok[r, i]), int(tok[r, i + 1])
            assert pairs.setdefault(prev, nxt) == nxt
    # labels are next tokens
    np.testing.assert_array_equal(np.asarray(a["labels"][:, :-1]),
                                  tok[:, 1:])
    assert (np.asarray(a["labels"][:, -1]) == -1).all()


def test_zipf_loads_moments():
    key = jax.random.PRNGKey(0)
    loads = np.asarray(zipf_expert_loads(key, 32, 10000, s=1.2))
    assert loads.sum() == 10000
    srt = np.sort(loads)[::-1]
    assert srt[0] > 3 * srt[-1]  # skewed
    flat = np.asarray(zipf_expert_loads(key, 32, 10000, s=0.0))
    assert flat.max() < 2.0 * flat.mean()


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    st = adamw_init(params)
    cfg = AdamWConfig(lr=0.2, grad_clip=0)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, st, gn = adamw_update(g, st, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_grad_clip():
    params = {"w": jnp.asarray([0.0])}
    st = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0)
    _, _, gn = adamw_update({"w": jnp.asarray([100.0])}, st, params, cfg)
    assert float(gn) == pytest.approx(100.0)


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, 1.0, warmup=10, total=100))
           for s in range(101)]
    assert lrs[0] == 0.0
    assert lrs[10] == pytest.approx(1.0, abs=1e-6)
    assert lrs[100] == pytest.approx(0.1, abs=1e-6)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))  # decays


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("qwen1.5-0.5b").smoke()
    params = dec.init_params(jax.random.PRNGKey(0), cfg)
    p = save_checkpoint(str(tmp_path), 7, params, {"arch": cfg.name})
    assert latest_checkpoint(str(tmp_path)) == p
    back = restore_checkpoint(p, params)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # structural mismatch is detected
    bad = dict(params)
    bad["extra"] = jnp.zeros((3,))
    with pytest.raises(KeyError):
        restore_checkpoint(p, bad)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "paper-gpt-32x1.3b"])
def test_training_reduces_loss(arch):
    """End-to-end: ~60 steps on the synthetic affine task must reduce loss
    (dense and MoE)."""
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(0)
    params = dec.init_params(key, cfg)
    ts = TrainState(master=params, opt=adamw_init(params),
                    solver=dec.init_solver_states(cfg, 1),
                    step=jnp.zeros((), jnp.int32))
    step = jax.jit(make_train_step(cfg, opt_cfg=AdamWConfig(lr=3e-3),
                                   n_micro=2))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, batch=16, noise=0.05,
                       n_maps=4, seed=1)
    losses = []
    for i, batch in zip(range(60), data):
        ts, m = step(ts, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < losses[0] - 0.4, losses[::10]


def test_solver_state_warm_start_threads_through_steps():
    cfg = get_config("paper-gpt-32x1.3b").smoke()
    key = jax.random.PRNGKey(0)
    params = dec.init_params(key, cfg)
    ts = TrainState(master=params, opt=adamw_init(params),
                    solver=dec.init_solver_states(cfg, 1),
                    step=jnp.zeros((), jnp.int32))
    step = jax.jit(make_train_step(cfg, n_micro=2))
    batch = make_batch(key, cfg.vocab, 8, 32)
    s0 = jax.tree_util.tree_leaves(ts.solver)[0].copy()
    ts, _ = step(ts, batch)
    s1 = jax.tree_util.tree_leaves(ts.solver)[0]
    assert float(jnp.abs(s1 - s0).max()) > 0  # state actually updated
