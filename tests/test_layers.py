"""Model layer unit tests: RoPE/M-RoPE, chunked vs dense attention, sliding
windows, GQA/MQA, RG-LRU scan, RWKV shift/state semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers.attention import (AttnConfig, attention,
                                           decode_attention, init_attention,
                                           init_kv_cache)
from repro.models.layers.rglru import (RGLRUState, init_rglru_block,
                                       rglru_block)
from repro.models.layers.rope import apply_mrope, apply_rope
from repro.models.layers.rwkv6 import (init_rwkv6_channel,
                                       rwkv6_channel_mix)


def test_mrope_reduces_to_rope_for_text():
    """Pure-text tokens: all three M-RoPE components equal the sequence
    index, which must reduce M-RoPE to plain RoPE [arXiv:2409.12191]."""
    b, h, t, d = 2, 3, 8, 32
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, h, t, d))
    pos = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    pos3 = jnp.broadcast_to(pos[..., None], (b, t, 3))
    a = apply_rope(x, pos, theta=1e6)
    m = apply_mrope(x, pos3, sections=(4, 6, 6), theta=1e6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(m), rtol=1e-5,
                               atol=1e-5)


def test_rope_relative_position_property():
    """Attention scores under RoPE depend only on relative offsets."""
    h, d = 1, 64
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, h, 1, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, h, 1, d))

    def score(pq, pk):
        qr = apply_rope(q, jnp.asarray([[pq]]))
        kr = apply_rope(k, jnp.asarray([[pk]]))
        return float(jnp.einsum("bhqd,bhkd->bhqk", qr, kr)[0, 0, 0, 0])

    np.testing.assert_allclose(score(5, 3), score(105, 103), rtol=1e-4)
    np.testing.assert_allclose(score(7, 0), score(1007, 1000), rtol=1e-4)


@pytest.mark.parametrize("window", [0, 32])
def test_chunked_attention_matches_dense(window):
    cfg = AttnConfig(d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                     window=window)
    key = jax.random.PRNGKey(2)
    p = init_attention(key, cfg)
    t = 128
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, t, 64)) * 0.5
    pos = jnp.broadcast_to(jnp.arange(t)[None], (2, t))
    dense = attention(p, cfg, x, pos, chunk_q=t)          # dense path
    chunked = attention(p, cfg, x, pos, chunk_q=16)       # chunked path
    chunked_u = attention(p, cfg, x, pos, chunk_q=16, unroll=True)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(chunked_u), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_masks_far_tokens():
    """A token beyond the window cannot influence the output."""
    cfg = AttnConfig(d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                     window=4)
    key = jax.random.PRNGKey(3)
    p = init_attention(key, cfg)
    t = 16
    x = jax.random.normal(key, (1, t, 32)) * 0.3
    pos = jnp.arange(t)[None]
    base = attention(p, cfg, x, pos)
    x2 = x.at[0, 0].add(10.0)  # token 0 far outside window of token 15
    pert = attention(p, cfg, x2, pos)
    np.testing.assert_allclose(np.asarray(base[0, -1]),
                               np.asarray(pert[0, -1]), rtol=1e-4, atol=1e-4)
    assert float(jnp.abs(base[0, 1] - pert[0, 1]).max()) > 1e-3


def test_mqa_kv_heads_shared():
    """MQA (kv=1): both query-head groups attend to the same kv stream."""
    cfg = AttnConfig(d_model=32, num_heads=4, num_kv_heads=1, head_dim=8)
    p = init_attention(jax.random.PRNGKey(4), cfg)
    assert p["wk"].shape == (32, 8)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 8, 32))
    out = attention(p, cfg, x, jnp.arange(8)[None])
    assert out.shape == (1, 8, 32) and jnp.isfinite(out).all()


def test_decode_ring_buffer_window():
    """Windowed decode ring buffer: after > window steps the output equals
    attention over only the last `window` tokens."""
    cfg = AttnConfig(d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                     window=4)
    key = jax.random.PRNGKey(6)
    p = init_attention(key, cfg)
    t = 10
    x = jax.random.normal(key, (1, t, 32)) * 0.5
    pos = jnp.arange(t)[None]
    ref = attention(p, cfg, x, pos)       # banded training attention
    cache = init_kv_cache(cfg, 1, t)
    outs = []
    for i in range(t):
        o, cache = decode_attention(p, cfg, x[:, i:i + 1], cache)
        outs.append(o[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_sequential():
    """Associative-scan RG-LRU == explicit sequential recurrence, and a
    split evaluation with carried state matches the full one."""
    dm, w, t = 16, 24, 12
    key = jax.random.PRNGKey(7)
    p = init_rglru_block(key, dm, w)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, t, dm)) * 0.5
    full, st_full = rglru_block(p, x)
    a, st_a = rglru_block(p, x[:, :7])
    b, st_b = rglru_block(p, x[:, 7:], state=st_a)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([a, b], axis=1)),
                               np.asarray(full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_b.h), np.asarray(st_full.h),
                               rtol=1e-4, atol=1e-4)


def test_rwkv_channel_mix_shift_state():
    dm, ff = 16, 32
    key = jax.random.PRNGKey(8)
    p = init_rwkv6_channel(key, dm, ff)
    x = jax.random.normal(jax.random.fold_in(key, 2), (1, 6, dm))
    full, last = rwkv6_channel_mix(p, x)
    a, la = rwkv6_channel_mix(p, x[:, :3])
    b, lb = rwkv6_channel_mix(p, x[:, 3:], state_prev=la)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([a, b], axis=1)),
                               np.asarray(full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lb), np.asarray(last))
